"""Multi-tenant sketch layout: thousands of streams in ONE fused bank.

The north-star service ingests per-user streams — the naive spelling is
one ``StreamSession`` per tenant, which pays one dispatch (and one
compiled-cache entry) per tenant per block.  The bank engine makes
tenancy a *routing* problem instead: one ``(T*S, k)`` bank, rows
tenant-major, a :class:`repro.sketch.bank.TenantRouter` mapping
composite keys ``(tenant << item_bits) | item`` onto the owning
tenant's rows, and the whole fleet ingests with a single
``update_block_fused`` launch per coalesced block.  Because composite
keys never collide across tenants and the fused partition path is
bit-identical to per-row ``block_update`` on each row's routed view
(tests/test_bank.py), every tenant's rows evolve exactly as an
independently built per-tenant sketch fed the same fragments — the
isolation bill tests/test_tenant.py pins across variants and delete
ratios.

Layout contract:

  * tenant t owns rows ``[t*S, (t+1)*S)`` (S = per-tenant hash shards,
    usually 1); its capacity budget ``cap_t`` splits ``ceil(cap_t/S)``
    per row via the engine's BLOCKED capacity masks — per-tenant
    capacity is a mask pattern, not a new state type;
  * queries gather the owner row only (``bank.query_rows``), per-tenant
    top-k reads the tenant's row slice only (``bank.topk_rows``) —
    neither can cross a tenant boundary by construction;
  * global ``topk`` speaks COMPOSITE keys (unpack with
    :func:`unpack_keys`): items of different tenants are different keys;
  * cold tenants spill to a tagged flat dict (:func:`spill_rows`) and
    re-admit exactly via :func:`admit_rows` — ``state.merge`` against
    the cleared (empty) rows reproduces the spilled content, and the
    row's BLOCKED capacity mask is re-imposed afterwards (merge relaxes
    rows to full width);
  * quantile tenancy composes through the dyadic bank over composite
    keys: per-tenant rank is a range difference inside the tenant's key
    range (:func:`tenant_rank_many`), per-tenant quantiles a lockstep
    search over the item part only (:func:`tenant_quantile_many`).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Sequence, Union

import numpy as np

import jax
import jax.numpy as jnp

from . import bank as bk
from . import dyadic as dy
from . import state as st
from .blocks import block_update
from .state import BLOCKED, EMPTY, SketchState, _INT_MAX

# mirrors api.LAYOUT_FREQUENCY (api imports this module post-registry;
# importing api here would be cyclic)
_LAYOUT_FREQUENCY = 1


# ---------------------------------------------------------------------------
# Composite routing keys
# ---------------------------------------------------------------------------

def tenant_bits_for(num_tenants: int) -> int:
    """High bits a composite key spends on the tenant id."""
    return (int(num_tenants) - 1).bit_length()


def pack_keys(tenants, items, item_bits: int):
    """Composite routing keys ``(tenant << item_bits) | item``.

    numpy inputs return int64 (so a malformed tenant/item pair overflows
    visibly and ``api.validate_block``'s int32 range check catches it);
    jax inputs stay int32 for in-trace use — the spec validation already
    guarantees ``tenant_bits + item_bits <= 31``.
    """
    if isinstance(tenants, jax.Array) or isinstance(items, jax.Array):
        t = jnp.asarray(tenants, jnp.int32)
        x = jnp.asarray(items, jnp.int32)
        return (t << item_bits) | x
    t = np.asarray(tenants, np.int64)
    x = np.asarray(items, np.int64)
    return (t << item_bits) | x


def unpack_keys(keys, item_bits: int):
    """Inverse of :func:`pack_keys`: ``(tenants, items)``."""
    mask = (1 << item_bits) - 1
    return keys >> item_bits, keys & mask


# ---------------------------------------------------------------------------
# The multi-tenant bank
# ---------------------------------------------------------------------------

class TenantBank(NamedTuple):
    """One ``(T*S, k)`` engine bank holding every tenant's counters.

    A thin wrapper (not a new state type): all engine invariants — the
    BLOCKED capacity masks, row-ownership, fused-update bit-identity —
    are the bank's own. ``num_shards``/``item_bits`` live in the spec /
    router, not here, so the pytree stays a pure array container.
    """

    bank: SketchState

    @property
    def num_rows(self) -> int:
        return self.bank.ids.shape[0]


def init_tenants(caps: Union[int, Sequence[int]],
                 num_tenants: Optional[int] = None,
                 num_shards: int = 1) -> TenantBank:
    """Empty multi-tenant bank; tenant t owns rows ``[t*S, (t+1)*S)``.

    ``caps``: per-tenant capacity (one int applied to ``num_tenants``
    tenants, or a per-tenant list). Each tenant's budget splits
    ``ceil(cap_t / S)`` per shard row — the same even split an
    independently built ``SketchSpec(shards=S)`` sketch of ``cap_t``
    counters applies, preserving per-tenant bit-identity.
    """
    if isinstance(caps, (int, np.integer)):
        assert num_tenants is not None and num_tenants >= 1
        caps = [int(caps)] * num_tenants
    else:
        caps = [int(c) for c in caps]
        assert num_tenants is None or num_tenants == len(caps)
    row_caps = [-(-c // num_shards) for c in caps for _ in range(num_shards)]
    return TenantBank(bank=bk.init(row_caps))


def router_for(num_tenants: int, item_bits: int,
               num_shards: int = 1) -> bk.TenantRouter:
    """The routing companion of :func:`init_tenants`."""
    return bk.TenantRouter(num_tenants, item_bits, num_shards)


def update_block(tb: TenantBank, keys, weights,
                 router: bk.TenantRouter, variant: int = 2) -> TenantBank:
    """One fused launch ingesting a composite-key block for ALL tenants."""
    return TenantBank(
        bank=bk.update_block_fused(tb.bank, keys, weights, router, variant))


@functools.partial(jax.jit, static_argnames=("router",))
def query_many_tenant(tb: TenantBank, keys: jax.Array,
                      router: bk.TenantRouter) -> jax.Array:
    """Estimated count per composite key, read from its owner row only."""
    keys = keys.astype(jnp.int32)
    return bk.query_rows(tb.bank, router.owner_of(keys), keys)


@functools.partial(jax.jit, static_argnames=("m", "num_shards", "item_bits"))
def topk_tenant(tb: TenantBank, tenant, m: int, *, num_shards: int,
                item_bits: int):
    """One tenant's top-m (raw items, counts); never crosses tenants.

    ``tenant`` may be a traced scalar — the row slice is a dynamic
    slice, so one compiled function serves every tenant.
    """
    start = jnp.asarray(tenant, jnp.int32) * num_shards
    sl = lambda x: jax.lax.dynamic_slice_in_dim(x, start, num_shards, 0)
    sub = SketchState(sl(tb.bank.ids), sl(tb.bank.counts), sl(tb.bank.errors))
    keys, vals = bk.topk_bank(sub, m)
    items = jnp.where(keys >= 0, keys & ((1 << item_bits) - 1), keys)
    return items, vals


@functools.partial(jax.jit, static_argnames=("m", "num_shards", "item_bits"))
def topk_tenants(tb: TenantBank, tenants: jax.Array, m: int, *,
                 num_shards: int, item_bits: int):
    """Batched per-tenant top-m: ONE row gather answers every
    subscription of a service tick.

    Returns ``(items, counts)`` of shape (n, m), row i = tenant
    ``tenants[i]``'s top-m raw items by estimated count.
    """
    tenants = tenants.astype(jnp.int32)
    rows = tenants[:, None] * num_shards + jnp.arange(
        num_shards, dtype=jnp.int32)[None, :]
    n = tenants.shape[0]
    ids = tb.bank.ids[rows].reshape(n, -1)        # (n, S*k)
    cnt = tb.bank.counts[rows].reshape(n, -1)
    score = jnp.where(ids < 0, jnp.int32(-2**31), cnt)
    vals, idx = jax.lax.top_k(score, m)
    keys = jnp.take_along_axis(ids, idx, axis=1)
    items = jnp.where(keys >= 0, keys & ((1 << item_bits) - 1), keys)
    return items, vals


# ---------------------------------------------------------------------------
# Cold-row spill / exact re-admission (the service's eviction path)
# ---------------------------------------------------------------------------

def tenant_rows(tenant: int, num_shards: int) -> np.ndarray:
    """The row indices tenant ``tenant`` owns (host-side helper)."""
    t = int(tenant)
    return np.arange(t * num_shards, (t + 1) * num_shards)


def extract_rows(bank: SketchState, rows) -> SketchState:
    """Row slice (n, k): the live content of those rows (spill payload)."""
    rows = jnp.asarray(rows, jnp.int32)
    return SketchState(bank.ids[rows], bank.counts[rows], bank.errors[rows])


def clear_rows(bank: SketchState, rows) -> SketchState:
    """Reset rows to empty, preserving their BLOCKED capacity masks."""
    rows = jnp.asarray(rows, jnp.int32)
    blocked = bank.ids[rows] == BLOCKED
    return SketchState(
        ids=bank.ids.at[rows].set(
            jnp.where(blocked, BLOCKED, EMPTY).astype(jnp.int32)),
        counts=bank.counts.at[rows].set(
            jnp.where(blocked, _INT_MAX, 0).astype(jnp.int32)),
        errors=bank.errors.at[rows].set(jnp.zeros_like(bank.errors[rows])),
    )


def admit_rows(bank: SketchState, rows, spilled: SketchState) -> SketchState:
    """Merge a spilled row bundle back into its rows; re-impose the rows'
    capacity masks.

    ``state.merge`` per row pairs exactly (both sides only ever held
    keys routed to that row).  Against *cleared* rows — the service
    re-admits BEFORE any new traffic reaches the tenant — the merge is
    content-exact: an empty side contributes no cross-term, and the
    merged row packs the spilled items (<= cap of them) at the front, so
    re-imposing the BLOCKED tail drops nothing and every query/top-k
    answer is preserved bit-for-bit (tests/test_tenant.py).  Against
    non-empty rows it is a standard capacity-``cap`` mergeable-summaries
    merge (top-cap survivors).
    """
    rows = jnp.asarray(rows, jnp.int32)
    live = SketchState(bank.ids[rows], bank.counts[rows], bank.errors[rows])
    over = live.ids == BLOCKED
    merged = jax.vmap(st.merge)(live, spilled)
    return SketchState(
        ids=bank.ids.at[rows].set(
            jnp.where(over, BLOCKED, merged.ids).astype(jnp.int32)),
        counts=bank.counts.at[rows].set(
            jnp.where(over, _INT_MAX, merged.counts).astype(jnp.int32)),
        errors=bank.errors.at[rows].set(
            jnp.where(over, 0, merged.errors).astype(jnp.int32)),
    )


def spill_rows(bank: SketchState, tenant: int, num_shards: int,
               item_bits: int) -> Dict[str, Any]:
    """Tagged flat numpy dict (npz-safe) of one tenant's rows.

    The cold-row spill format (DESIGN.md §15): the standard frequency
    triple restricted to the tenant's (S, k) row slice, plus enough
    metadata (``tenant``, ``shards``, ``item_bits``) to re-admit it into
    the right rows of a compatible bank.
    """
    sp = extract_rows(bank, tenant_rows(tenant, num_shards))
    return {
        "layout": np.int32(_LAYOUT_FREQUENCY),
        "tenant": np.int32(tenant),
        "shards": np.int32(num_shards),
        "item_bits": np.int32(item_bits),
        "ids": np.asarray(sp.ids),
        "counts": np.asarray(sp.counts),
        "errors": np.asarray(sp.errors),
    }


def admit_spill(bank: SketchState, d: Dict[str, Any]) -> SketchState:
    """Re-admit a :func:`spill_rows` dict into its tenant's rows."""
    for key in ("tenant", "shards", "ids", "counts", "errors"):
        if key not in d:
            raise ValueError(
                f"spill dict is missing key {key!r} (truncated write?); a "
                f"tenant spill carries tenant/shards/item_bits + the "
                f"ids/counts/errors triple")
    num_shards = int(np.asarray(d["shards"]))
    rows = tenant_rows(int(np.asarray(d["tenant"])), num_shards)
    spilled = SketchState(
        ids=jnp.asarray(np.asarray(d["ids"]), jnp.int32),
        counts=jnp.asarray(np.asarray(d["counts"]), jnp.int32),
        errors=jnp.asarray(np.asarray(d["errors"]), jnp.int32),
    )
    return admit_rows(bank, rows, spilled)


# ---------------------------------------------------------------------------
# Per-tenant quantiles over a composite-key dyadic bank
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("item_bits",))
def tenant_rank_many(state: dy.DyadicState, tenant, xs: jax.Array,
                     item_bits: int) -> jax.Array:
    """Per-tenant rank(x) = |{v <= x, v in tenant}| as a range difference.

    The dyadic bank is built over composite keys, so the tenant's values
    occupy the contiguous key range [base, base + 2^item_bits); rank
    within the tenant is rank(base + x) - rank(base - 1). For tenant 0
    the left edge is rank(-1) = 0 exactly. Error adds the two range
    endpoints' dyadic estimates: <= 2x the single-rank bound.
    """
    base = jnp.asarray(tenant, jnp.int32) << item_bits
    lo = dy.rank_many(state, (base - 1)[None])[0]
    return dy.rank_many(state, base + xs.astype(jnp.int32)) - lo


@functools.partial(jax.jit, static_argnames=("item_bits",))
def tenant_mass(state: dy.DyadicState, tenant, item_bits: int) -> jax.Array:
    """One tenant's live mass |F_t|₁ (range mass of its key range)."""
    base = jnp.asarray(tenant, jnp.int32) << item_bits
    edges = jnp.stack([base - 1, base + (1 << item_bits) - 1])
    r = dy.rank_many(state, edges)
    return r[1] - r[0]


@functools.partial(jax.jit, static_argnames=("item_bits",))
def tenant_quantile_many(state: dy.DyadicState, tenant, qs: jax.Array,
                         item_bits: int) -> jax.Array:
    """Per-tenant quantiles: lockstep search over the ITEM part only.

    Reuses ``dy.lockstep_quantile_search`` with the tenant's offset rank
    function and range mass — the universe searched is [0, 2^item_bits),
    item_bits + 1 rounds, regardless of how many tenants share the bank.
    """
    base = jnp.asarray(tenant, jnp.int32) << item_bits
    edges = jnp.stack([base - 1, base + (1 << item_bits) - 1])
    r = dy.rank_many(state, edges)
    lo, mass = r[0], r[1] - r[0]
    rank_fn = lambda xs: dy.rank_many(state, base + xs) - lo
    return dy.lockstep_quantile_search(
        rank_fn, mass, item_bits, qs.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Serial oracle: each row updated independently on its routed view
# ---------------------------------------------------------------------------

def reference_row_update(row_state: SketchState, keys, weights,
                         router: bk.TenantRouter, row: int,
                         variant: int = 2) -> SketchState:
    """One row's independent oracle step: ``blocks.block_update`` on the
    row's own routed view of a raw composite-key block.

    The per-row ground truth the fused launch must match bit-for-bit
    (the ``sharded.update_block_serial_reference`` idiom, usable on a
    row subset so the service bench can sample its parity bill instead
    of replaying all T*S rows).
    """
    keys = jnp.asarray(keys, jnp.int32)
    weights = jnp.asarray(weights, jnp.int32)
    order = bk.sort_block(keys, router.universe_bits)
    s_keys = keys[order]
    w_row = jnp.where(router.owner_of(s_keys) == row, weights[order], 0)
    return block_update(row_state, s_keys, w_row, variant,
                        assume_sorted=True)


def update_serial_reference(tb: TenantBank, keys, weights,
                            router: bk.TenantRouter,
                            variant: int = 2) -> TenantBank:
    """Reference: route, then update every row SERIALLY (python loop)."""
    outs = [
        reference_row_update(
            jax.tree.map(lambda x: x[r], tb.bank), keys, weights, router, r,
            variant)
        for r in range(router.num_rows)
    ]
    return TenantBank(bank=jax.tree.map(lambda *xs: jnp.stack(xs), *outs))


# ---------------------------------------------------------------------------
# The SketchSpec(tenants=...) adapter
# ---------------------------------------------------------------------------

class TenantAdapter:
    """``SketchSpec(tenants=T)`` frequency layout: one (T*S, k) bank.

    Registered under the registry's ``tenants`` axis for both sharded
    and unsharded specs (``shards`` means per-tenant hash shards here).
    ``update`` derives the tenant count from the STATE shape, never from
    ``spec.tenants`` — the session's compiled-ingest cache normalizes
    tenant specs sharing a layout onto one entry
    (``session.ingest_cache_spec``), so one trace must serve any fleet
    size (jit retraces per state shape, which is exactly the layout).
    """

    def _shards(self, spec) -> int:
        return spec.shards or 1

    def _tenants_of(self, spec, state) -> int:
        return state.bank.ids.shape[0] // self._shards(spec)

    def _router(self, spec, state) -> bk.TenantRouter:
        return bk.TenantRouter(self._tenants_of(spec, state), spec.bits,
                               self._shards(spec))

    def make(self, spec) -> TenantBank:
        caps = spec.tenant_caps
        if caps is None:
            # even split of the total budget, ceil so every tenant gets
            # at least one counter
            caps = [-(-spec.capacity // spec.tenants)] * spec.tenants
        return init_tenants(list(caps), num_shards=self._shards(spec))

    def update(self, spec, state, items, weights):
        return update_block(state, items, weights,
                            self._router(spec, state), spec.variant_id)

    def query_many(self, spec, state, items):
        return query_many_tenant(state, items, self._router(spec, state))

    def topk(self, spec, state, m):
        """Global top-m across ALL tenants — returns COMPOSITE keys
        (items of different tenants are different keys; unpack with
        :func:`unpack_keys`). Per-tenant top-k is ``topk_tenant``."""
        return bk.topk_bank(state.bank, m)

    def topk_tenant(self, spec, state, tenant, m):
        return topk_tenant(state, tenant, m, num_shards=self._shards(spec),
                           item_bits=spec.bits)

    def rank_many(self, spec, state, xs):
        raise ValueError(
            f"rank/quantile queries need kind='quantile'; this spec is "
            f"kind={spec.kind!r}. Tenant quantiles run on a quantile spec "
            f"over composite keys (tenant_rank_many / "
            f"tenant_quantile_many).")

    quantile_many = rank_many

    def merge(self, spec, a, b):
        # rows pair exactly (same router); merged rows relax to full
        # width k — same capacity behavior as the dyadic layer merge
        return TenantBank(bank=bk.merge_banks(a.bank, b.bank))

    def consolidate(self, spec, state):
        # folding rows would collapse the tenancy the layout exists for;
        # the compact per-tenant view is spill_rows / topk_tenant
        return state

    def save(self, spec, state) -> Dict[str, Any]:
        return {
            "layout": np.int32(_LAYOUT_FREQUENCY),
            "ids": np.asarray(state.bank.ids),
            "counts": np.asarray(state.bank.counts),
            "errors": np.asarray(state.bank.errors),
            "tenants": np.int32(self._tenants_of(spec, state)),
            "shards": np.int32(spec.shards or 0),
            "item_bits": np.int32(spec.bits),
        }

    def restore(self, spec, d) -> TenantBank:
        fields = SketchState(
            ids=jnp.asarray(np.asarray(d["ids"]), jnp.int32),
            counts=jnp.asarray(np.asarray(d["counts"]), jnp.int32),
            errors=jnp.asarray(np.asarray(d["errors"]), jnp.int32),
        )
        want = spec.tenants * self._shards(spec)
        got = fields.ids.shape[0]
        if got != want:
            raise ValueError(
                f"checkpoint has {got} rows but the spec's layout "
                f"(tenants={spec.tenants} x shards={self._shards(spec)}) "
                f"needs {want}; restore through infer_spec(spec, d)")
        return TenantBank(bank=fields)


__all__ = [
    "TenantBank",
    "TenantAdapter",
    "tenant_bits_for",
    "pack_keys",
    "unpack_keys",
    "init_tenants",
    "router_for",
    "update_block",
    "query_many_tenant",
    "topk_tenant",
    "topk_tenants",
    "tenant_rows",
    "extract_rows",
    "clear_rows",
    "admit_rows",
    "spill_rows",
    "admit_spill",
    "tenant_rank_many",
    "tenant_mass",
    "tenant_quantile_many",
    "reference_row_update",
    "update_serial_reference",
]
