"""One spec-driven front-end over every SpaceSaving± backend.

After the engine refactors the repo exposes four client surfaces with
four divergent spellings (``blocks.block_update``,
``sharded.update_block``, ``dyadic.update_block(..., path=)``,
``dyadic_sharded.update_block``).  The SpaceSaving± Family follow-up
(PAPERS.md) treats all of them as ONE mergeable family behind one
contract; this module is that contract as code:

  * :class:`SketchSpec` — a frozen (hashable → jit-static) description
    of WHAT to build: ``kind`` ('frequency' | 'quantile'), sizing
    (``k`` total counters or the paper's ``eps``+``alpha`` Thm-4 /
    §4.2 prescription via the shared ``capacity_for`` /
    ``dyadic_layer_capacities`` helpers), ``variant``
    ('sspm' | 'lazy'), ``shards`` (None = single-host), ``bits``
    (universe bound; required for quantile kinds) and ``backend``
    ('bank' fused engine | 'block' vmapped two-phase | 'kernel' Pallas
    | 'serial' scan baseline).

  * an **adapter registry** — each (kind, sharded?) pair registers one
    adapter object translating the uniform surface onto its client
    module.  New layouts plug in by registering an adapter; consumers
    never learn a fifth spelling.

  * the **uniform functional surface** — ``make``, ``update``,
    ``query``/``query_many``/``topk``, ``rank``/``rank_many``/
    ``quantile``/``quantile_many`` (quantile kinds only, with
    actionable errors otherwise), ``merge``, ``consolidate``,
    ``save``/``restore``.  Every call is bit-identical to the direct
    client/engine spelling it wraps — pinned across the full spec grid
    by tests/test_api_parity.py.

Checkpoints (``save``/``restore``) are flat dicts of numpy-compatible
arrays carrying an integer ``layout`` tag, and ``restore`` also accepts
the pre-redesign ``stats._SketchBank`` layouts (``ids/counts/errors``
[+ ``shards``], no tag) so existing ``train/checkpoint.py`` checkpoints
keep loading.

The stateful companion (host-side buffering, padding, cached donated
jitted ingest, windowed deletion scheduling) is
:class:`repro.sketch.session.StreamSession`.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.quantiles import dyadic_layer_capacities
from repro.core.spacesaving import capacity_for

from . import bank as bk
from . import blocks
from . import dyadic as dy
from . import dyadic_sharded as dysh
from . import sharded as shd
from . import state as st
from .state import VARIANT_LAZY, VARIANT_SSPM, SketchState

KINDS = ("frequency", "quantile")
# variant name -> engine-layer integer. The family variants ('double',
# 'unbiased') map to VARIANT_SSPM because their underlying banks run
# plain SpaceSaving updates on insert-only streams (deletions feed the
# second bank as insertions — repro.sketch.family); the spec-level name
# still selects the family adapter via the registry axis.
VARIANTS = {"sspm": VARIANT_SSPM, "lazy": VARIANT_LAZY,
            "double": VARIANT_SSPM, "unbiased": VARIANT_SSPM}
FAMILY_VARIANTS = ("double", "unbiased")
BACKENDS = ("bank", "block", "kernel", "serial")

# integer layout tags (strings would not survive the np.savez round trip
# of train/checkpoint.py); absence of the tag marks a pre-redesign dict.
LAYOUT_FREQUENCY = 1
LAYOUT_QUANTILE = 2
LAYOUT_DOUBLE = 3     # two coupled banks (Double / unbiased SpaceSaving±)
LAYOUT_CRPRECIS = 4   # CR-precis prime-modulus counter array


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Frozen, hashable description of one SpaceSaving± summary.

    Sizing: pass exactly one of ``k`` (total live counters — split per
    layer for quantile kinds by ``dyadic_layer_capacities``, per shard
    for hash-sharded frequency banks) or ``eps`` (+ ``alpha``), the
    paper's Thm-4 / §4.2 prescription (``capacity_for`` /
    ``dyadic_layer_capacities``).

    ``bits`` bounds the item universe to [0, 2^bits).  Required for
    quantile kinds (it fixes the dyadic layer count); optional for
    frequency kinds, where it only enables the packed single-sort
    router (``bank.sort_block``).

    ``backend`` picks the execution path, NOT the semantics — every
    backend of a given spec produces bit-identical states:
      'bank'   fused bank-engine launch (production default);
      'block'  per-row vmapped two-phase update;
      'kernel' fused tiled Pallas launch (interpret resolved by
               repro.platform: compiled iff an accelerator is attached);
      'serial' sequential scan baseline (A/B reference).
    ``backends_for(kind, shards)`` lists what a combination supports.

    ``tenants=T`` selects the multi-tenant bank layout
    (``repro.sketch.tenant``): one (T·S, k) bank ingesting composite
    keys ``(tenant << bits) | item``, rows tenant-major, with ``shards``
    meaning per-tenant hash shards. ``bits`` becomes required (it is
    the per-tenant item-universe bound composite keys are packed
    against). Size with ``k``/``eps`` (split evenly across tenants) or
    ``tenant_caps`` (one capacity per tenant — per-tenant BLOCKED
    masks; base variants only).
    """

    kind: str = "frequency"
    k: Optional[int] = None
    eps: Optional[float] = None
    alpha: float = 2.0
    variant: str = "sspm"
    shards: Optional[int] = None
    bits: Optional[int] = None
    backend: str = "bank"
    tenants: Optional[int] = None
    tenant_caps: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"SketchSpec.kind must be one of {KINDS}, got {self.kind!r}")
        if self.variant not in VARIANTS:
            raise ValueError(
                f"SketchSpec.variant must be one of {tuple(VARIANTS)}, got "
                f"{self.variant!r} (the integer VARIANT_* constants belong "
                f"to the engine layer; the spec speaks names)")
        if self.backend not in BACKENDS + ("crprecis",):
            raise ValueError(
                f"SketchSpec.backend must be one of "
                f"{BACKENDS + ('crprecis',)}, got {self.backend!r}")
        if self.tenant_caps is not None and not isinstance(
                self.tenant_caps, tuple):
            # the spec must stay hashable (jit-static); accept any
            # sequence but store the canonical tuple
            object.__setattr__(self, "tenant_caps",
                               tuple(int(c) for c in self.tenant_caps))
        n_sizing = ((self.k is not None) + (self.eps is not None)
                    + (self.tenant_caps is not None))
        if n_sizing != 1:
            raise ValueError(
                "size the spec with exactly one of k (total counters), "
                "eps (+ alpha, paper Thm 4 / §4.2) or tenant_caps "
                f"(per-tenant counters); got k={self.k}, eps={self.eps}, "
                f"tenant_caps={self.tenant_caps}")
        if self.kind == "quantile" and self.bits is None:
            raise ValueError(
                "kind='quantile' needs bits (the dyadic universe bound "
                "[0, 2^bits) fixes the layer count)")
        if self.shards is not None and self.shards < 1:
            raise ValueError(f"shards must be >= 1 or None, got {self.shards}")
        if self.variant in FAMILY_VARIANTS and self.kind != "frequency":
            raise ValueError(
                f"variant={self.variant!r} (the Double/unbiased "
                f"SpaceSaving± family) is a frequency-kind layout; "
                f"kind={self.kind!r} does not support it")
        if self.tenant_caps is not None and self.tenants is None:
            raise ValueError(
                "tenant_caps sizes the multi-tenant layout; set tenants=T "
                "(the per-tenant capacity list has no meaning without it)")
        if self.tenants is not None:
            if self.tenants < 1:
                raise ValueError(
                    f"tenants must be >= 1 or None, got {self.tenants}")
            if self.kind != "frequency":
                raise ValueError(
                    "tenants=T is a frequency-kind layout; per-tenant "
                    "quantiles run a plain quantile spec over composite "
                    "keys instead (repro.sketch.tenant.tenant_rank_many)")
            if self.bits is None:
                raise ValueError(
                    "tenants=T needs bits (the per-tenant item-universe "
                    "bound composite keys (tenant << bits) | item are "
                    "packed against)")
            tb = (self.tenants - 1).bit_length()
            if tb + self.bits > 31:
                raise ValueError(
                    f"composite keys need tenant_bits + bits <= 31 to fit "
                    f"the int32 id dtype; got tenants={self.tenants} "
                    f"({tb} bits) with bits={self.bits}")
            if self.tenant_caps is not None:
                if len(self.tenant_caps) != self.tenants:
                    raise ValueError(
                        f"tenant_caps has {len(self.tenant_caps)} entries "
                        f"for tenants={self.tenants}")
                if min(self.tenant_caps) < 1:
                    raise ValueError(
                        f"every tenant needs >= 1 counter; got "
                        f"min(tenant_caps)={min(self.tenant_caps)}")
                if self.variant in FAMILY_VARIANTS:
                    raise ValueError(
                        "tenant_caps (per-tenant BLOCKED masks) is a "
                        "base-layout feature; the family's k_I/k_D split "
                        "sizes evenly — use k or eps with "
                        f"variant={self.variant!r}")
        if self.backend not in backends_for(self.kind, self.shards,
                                            self.variant, self.tenants):
            raise ValueError(
                f"backend {self.backend!r} is not supported for "
                f"kind={self.kind!r}, shards={self.shards}, "
                f"variant={self.variant!r}, tenants={self.tenants}; "
                f"supported: "
                f"{backends_for(self.kind, self.shards, self.variant, self.tenants)}")

    @property
    def variant_id(self) -> int:
        """The engine-layer integer variant (VARIANT_LAZY / VARIANT_SSPM)."""
        return VARIANTS[self.variant]

    @property
    def capacity(self) -> int:
        """Resolved total live-counter budget of one frequency summary."""
        if self.kind != "frequency":
            raise ValueError(
                "capacity is the frequency-kind budget; quantile kinds size "
                "per layer — use layer_capacities()")
        if self.tenant_caps is not None:
            return int(sum(self.tenant_caps))
        if self.k is not None:
            return int(self.k)
        return capacity_for(self.eps, self.alpha,
                            "lazy" if self.variant == "lazy" else "ss_pm")

    def layer_capacities(self) -> list:
        """Per-layer counters of one quantile summary (shared helper)."""
        if self.kind != "quantile":
            raise ValueError("layer_capacities() applies to quantile kinds")
        return dyadic_layer_capacities(
            self.bits, total_counters=self.k, eps=self.eps, alpha=self.alpha)


def backends_for(kind: str, shards: Optional[int], variant: str = "sspm",
                 tenants: Optional[int] = None) -> Tuple[str, ...]:
    """Execution paths a (kind, sharded?, variant, tenants?) combination
    supports.

    The family variants run only through the fused bank engine (their
    coupled banks are engine banks by construction); the deterministic
    CR-precis layout is reachable as ``backend='crprecis'`` on unsharded
    sspm frequency specs (it is a different summary, not an execution
    path of the SpaceSaving± store — sharding it would break its linear
    row arithmetic for no space gain). Multi-tenant layouts are
    frequency-kind fused-engine banks only (their whole point is the
    one-launch routed ingest).
    """
    if tenants:
        return ("bank",) if kind == "frequency" else ()
    if variant in FAMILY_VARIANTS:
        return ("bank",) if kind == "frequency" else ()
    if kind == "quantile" and shards:
        # the composed shard × level bank only runs the fused engine
        # (its shard_map path is selected automatically under a mesh)
        return ("bank",)
    if kind == "frequency" and not shards:
        # crprecis has no lazy/sspm distinction; it hangs off the sspm
        # default so the grid carries exactly one cell for it
        extra = ("crprecis",) if variant == "sspm" else ()
        return BACKENDS + extra
    return BACKENDS


def variants_for(kind: str) -> Tuple[str, ...]:
    """Variant names a kind supports (the family is frequency-only)."""
    return tuple(VARIANTS) if kind == "frequency" else ("sspm", "lazy")


# ---------------------------------------------------------------------------
# Input validation: one home for the block conventions
# ---------------------------------------------------------------------------

def validate_block(spec: SketchSpec, items, weights, *,
                   prior_mass: int = 0) -> int:
    """Check one (items, weights) block against the package conventions.

    The conventions every adapter assumes (DESIGN.md §11): item ids are
    non-negative int (negative values are the EMPTY/BLOCKED sentinels),
    weight > 0 inserts, < 0 deletes, weight == 0 marks padding (the id
    of a zero-weight slot is ignored), items and weights are equal-length
    1-D, and quantile kinds need every REAL (nonzero-weight) item inside
    the dyadic universe [0, 2^bits).

    ``prior_mass`` is the positive mass already merged into the state
    this block is headed for (sessions track it across ingests).  A
    counter can hold at most that much, so the block is rejected when
    ``prior_mass`` plus the block's worst PER-ITEM net weight could
    carry a counter past int32 — the per-block magnitude-sum check
    alone misses a saturated counter meeting a near-rail state, which
    is exactly the precondition the SK201 range pass assumes
    (``repro.analysis.range_interp``).  Returns the block's positive
    mass (0 for traced blocks) so callers can accumulate it.

    Traced (jit-abstract) inputs skip the value checks — validation
    happens where values exist: at the host boundary
    (:class:`repro.sketch.session.StreamSession` and the non-jitted
    ``api.update``), never inside a compiled ingest.
    """
    traced = isinstance(items, jax.core.Tracer) or isinstance(
        weights, jax.core.Tracer)
    i_shape = np.shape(items)
    w_shape = np.shape(weights)
    if len(i_shape) != 1:
        raise ValueError(
            f"items must be 1-D (one block of ids), got shape {i_shape}; "
            f"flatten batches host-side or use StreamSession.extend")
    if i_shape != w_shape:
        raise ValueError(
            f"items/weights length mismatch: {i_shape} vs {w_shape}; pad "
            f"the short side with weight-0 entries (the padding convention)")
    if traced:
        return 0
    i = np.asarray(items)
    w = np.asarray(weights)
    if i.dtype.kind not in "iu" or w.dtype.kind not in "iu":
        raise ValueError(
            f"items/weights must be integer arrays (ids and signed counts), "
            f"got dtypes {i.dtype}/{w.dtype}")
    real = w != 0
    if (i[real] < 0).any():
        bad = int(i[real][i[real] < 0][0])
        raise ValueError(
            f"negative item id {bad}: ids must be >= 0 (negative ids are "
            f"the EMPTY/BLOCKED sentinels). To pad a block, keep any id "
            f"and set its weight to 0.")
    int32_max = np.iinfo(np.int32).max
    if (i[real].astype(np.int64) > int32_max).any():
        bad = int(i[real][i[real].astype(np.int64) > int32_max][0])
        raise ValueError(
            f"item id {bad} exceeds int32 (the device-side id dtype); "
            f"hash or re-bucket ids into [0, 2^31) before ingest")
    if np.abs(w.astype(np.int64)).max(initial=0) > int32_max:
        raise ValueError(
            "weights must fit int32 (the device-side count dtype)")
    wsum = int(np.abs(w.astype(np.int64)).sum())
    if wsum > int32_max:
        # a single block whose weight magnitudes sum past int32 could
        # push a counter through _INT_MAX mid-aggregation; the fused
        # cores saturate rather than wrap, but saturation loses mass —
        # reject at the host boundary where the caller can still split.
        raise ValueError(
            f"block weight magnitudes sum to {wsum} > int32 max "
            f"({int32_max}): a single block this heavy could overflow "
            f"the int32 counters (adds saturate, losing mass). Split "
            f"the block or rescale the weights.")
    # per-item cumulative mass vs. near-rail state: a counter already
    # holding up to prior_mass takes this block's NET weight for its
    # item in one merge, so the worst per-item net (not the block sum)
    # is what must still fit under the rail.
    pos_mass = int(w.astype(np.int64).clip(min=0).sum())
    if prior_mass and pos_mass:
        w64 = w[real].astype(np.int64)
        uniq, inv = np.unique(i[real], return_inverse=True)
        net = np.zeros(uniq.size, dtype=np.int64)
        np.add.at(net, inv, w64)
        worst = int(net.max(initial=0))
        if worst > 0 and int(prior_mass) + worst > int32_max:
            bad = int(uniq[int(np.argmax(net))])
            raise ValueError(
                f"item {bad} accumulates net weight {worst} in this "
                f"block while the target state already holds up to "
                f"{int(prior_mass)} positive mass: its counter could "
                f"cross int32 max ({int32_max}) mid-merge (adds "
                f"saturate, losing mass). Split the block, rescale "
                f"weights, or checkpoint-and-reset the session.")
    if spec.kind == "quantile":
        hi = 1 << spec.bits
        if (i[real] >= hi).any():
            bad = int(i[real][i[real] >= hi][0])
            raise ValueError(
                f"item {bad} is outside the dyadic universe [0, 2^{spec.bits}"
                f"); raise SketchSpec.bits or bucket ids before ingest")
    if spec.tenants is not None:
        hi = spec.tenants << spec.bits
        if (i[real].astype(np.int64) >= hi).any():
            bad = int(i[real][i[real].astype(np.int64) >= hi][0])
            raise ValueError(
                f"composite key {bad} is outside the tenant key space "
                f"[0, {spec.tenants} << {spec.bits}); pack keys with "
                f"tenant.pack_keys(tenant, item, item_bits={spec.bits}) "
                f"and keep items inside [0, 2^{spec.bits})")
    return pos_mass


# ---------------------------------------------------------------------------
# Adapters: the four client layouts behind one protocol
# ---------------------------------------------------------------------------

def _no_rank(spec: SketchSpec):
    raise ValueError(
        f"rank/quantile queries need kind='quantile'; this spec is "
        f"kind={spec.kind!r}. Build a SketchSpec(kind='quantile', "
        f"bits=..., ...) to get the dyadic bank.")


class _FrequencyAdapter:
    """shards=None frequency: the flat (k,) SketchState."""

    def make(self, spec: SketchSpec) -> SketchState:
        return st.init(spec.capacity)

    def update(self, spec, state, items, weights):
        v = spec.variant_id
        if spec.backend == "bank":
            return bk.update_single(state, items, weights, v, spec.bits)
        if spec.backend == "block":
            return blocks.block_update(state, items, weights, v)
        if spec.backend == "serial":
            return blocks.block_update_serial(state, items, weights, v)
        # 'kernel': the fused tiled launch on the flat sketch viewed as a
        # one-row bank (same routing as bank.update_single, so the fused
        # partition path and this stay bit-identical); interpret resolves
        # platform-side (repro.platform) instead of hardcoding True.
        from repro.kernels.sketch_update.ops import sketch_block_update_fused
        from repro.sketch.bank import HashShardRouter

        router = HashShardRouter(1, spec.bits)
        row_items, row_weights = router.route_dense(
            items.astype(jnp.int32), weights.astype(jnp.int32))
        bank1 = jax.tree.map(lambda x: x[None], state)
        out = sketch_block_update_fused(bank1, row_items, row_weights, v)
        return jax.tree.map(lambda x: x[0], out)

    def query_many(self, spec, state, items):
        return st.query_many(state, items)

    def topk(self, spec, state, m):
        return st.topk(state, m)

    def rank_many(self, spec, state, xs):
        _no_rank(spec)

    quantile_many = rank_many

    def merge(self, spec, a, b):
        return st.merge(a, b)

    def consolidate(self, spec, state):
        return state

    def save(self, spec, state) -> Dict[str, Any]:
        return {
            "layout": np.int32(LAYOUT_FREQUENCY),
            "ids": np.asarray(state.ids),
            "counts": np.asarray(state.counts),
            "errors": np.asarray(state.errors),
        }

    def restore(self, spec, d) -> SketchState:
        return _sketch_fields(d)


class _ShardedFrequencyAdapter:
    """shards=S frequency: the hash-partitioned ShardedSketch bank."""

    # spec backend -> sharded.update_block path name
    _PATHS = {"bank": "auto", "block": "vmap", "kernel": "kernel"}

    def make(self, spec: SketchSpec) -> shd.ShardedSketch:
        return shd.init(spec.capacity, spec.shards)

    def update(self, spec, state, items, weights):
        v = spec.variant_id
        if spec.backend == "serial":
            return shd.update_block_serial_reference(
                state, items, weights, v, universe_bits=spec.bits)
        return shd.update_block(state, items, weights, v,
                                universe_bits=spec.bits,
                                path=self._PATHS[spec.backend])

    def query_many(self, spec, state, items):
        return shd.query_many(state, items)

    def topk(self, spec, state, m):
        return shd.topk(state, m)

    def rank_many(self, spec, state, xs):
        _no_rank(spec)

    quantile_many = rank_many

    def merge(self, spec, a, b):
        return shd.merge(a, b)

    def consolidate(self, spec, state):
        return shd.consolidate(state)

    def save(self, spec, state) -> Dict[str, Any]:
        return {
            "layout": np.int32(LAYOUT_FREQUENCY),
            "ids": np.asarray(state.bank.ids),
            "counts": np.asarray(state.bank.counts),
            "errors": np.asarray(state.bank.errors),
            "shards": np.int32(spec.shards),
        }

    def restore(self, spec, d) -> shd.ShardedSketch:
        fields = _sketch_fields(d)
        got = fields.ids.shape[0]
        if got != spec.shards:
            raise ValueError(
                f"checkpoint has {got} shards, spec asks for {spec.shards}; "
                f"restore with a matching spec (or consolidate first)")
        return shd.ShardedSketch(bank=fields)


class _DyadicAdapter:
    """shards=None quantile: the (bits, k) dyadic layer bank."""

    def make(self, spec: SketchSpec) -> dy.DyadicState:
        return dy.init(spec.bits, total_counters=spec.k, eps=spec.eps,
                       alpha=spec.alpha)

    def update(self, spec, state, items, weights):
        return dy.update_block(state, items, weights, spec.variant_id,
                               path=spec.backend)

    def query_many(self, spec, state, items):
        # leaf-layer reads: layer 0 monitors x >> 0 = x itself
        return st.query_many(jax.tree.map(lambda x: x[0], state.bank), items)

    def topk(self, spec, state, m):
        # BLOCKED-aware flat top-k of the leaf row (st.topk would surface
        # the INT_MAX counts of capacity-padding slots)
        return bk.topk_bank(jax.tree.map(lambda x: x[:1], state.bank), m)

    def rank_many(self, spec, state, xs):
        return dy.rank_many(state, xs)

    def quantile_many(self, spec, state, qs):
        return dy.quantile_many(state, qs)

    def merge(self, spec, a, b):
        return dy.merge(a, b)

    def consolidate(self, spec, state):
        return state

    def save(self, spec, state) -> Dict[str, Any]:
        return {
            "layout": np.int32(LAYOUT_QUANTILE),
            "ids": np.asarray(state.bank.ids),
            "counts": np.asarray(state.bank.counts),
            "errors": np.asarray(state.bank.errors),
            "mass": np.int32(state.mass),
        }

    def restore(self, spec, d) -> dy.DyadicState:
        return dy.DyadicState(bank=_sketch_fields(d),
                              mass=jnp.int32(np.asarray(d["mass"])))


class _DyadicShardedAdapter:
    """shards=S quantile: the mesh-distributed shard × level bank."""

    def make(self, spec: SketchSpec) -> dysh.DyadicShardedState:
        return dysh.init(spec.bits, spec.shards, total_counters=spec.k,
                         eps=spec.eps, alpha=spec.alpha)

    def update(self, spec, state, items, weights):
        return dysh.update_block(state, items, weights, spec.variant_id,
                                 path="auto")

    def query_many(self, spec, state, items):
        # leaf-layer reads from each id's owner (shard, level-0) row
        items = items.astype(jnp.int32)
        owner = bk.shard_of(items, state.num_shards)
        leaf = jax.tree.map(lambda x: x[:, 0], state.bank)  # (S, k)
        return bk.query_rows(leaf, owner, items)

    def topk(self, spec, state, m):
        return bk.topk_bank(jax.tree.map(lambda x: x[:, 0], state.bank), m)

    def rank_many(self, spec, state, xs):
        return dysh.rank_many(state, xs)

    def quantile_many(self, spec, state, qs):
        return dysh.quantile_many(state, qs)

    def merge(self, spec, a, b):
        return dysh.merge(a, b)

    def consolidate(self, spec, state):
        return dysh.consolidate(state)

    def save(self, spec, state) -> Dict[str, Any]:
        return {
            "layout": np.int32(LAYOUT_QUANTILE),
            "ids": np.asarray(state.bank.ids),
            "counts": np.asarray(state.bank.counts),
            "errors": np.asarray(state.bank.errors),
            "mass": np.int32(state.mass),
            "shards": np.int32(spec.shards),
        }

    def restore(self, spec, d) -> dysh.DyadicShardedState:
        fields = _sketch_fields(d)
        got = fields.ids.shape[0]
        if got != spec.shards:
            raise ValueError(
                f"checkpoint has {got} shards, spec asks for {spec.shards}; "
                f"restore with a matching spec (or consolidate first)")
        return dysh.DyadicShardedState(
            bank=fields, mass=jnp.int32(np.asarray(d["mass"])))


def _sketch_fields(d) -> SketchState:
    return SketchState(
        ids=jnp.asarray(np.asarray(d["ids"]), jnp.int32),
        counts=jnp.asarray(np.asarray(d["counts"]), jnp.int32),
        errors=jnp.asarray(np.asarray(d["errors"]), jnp.int32),
    )


# registry key: (kind, sharded?, axis, tenants?) — new layouts register
# here instead of teaching every consumer a fifth client module. The
# third axis discriminates same-kind layout families: 'base' is the
# plain SpaceSaving± store, 'double'/'unbiased' the coupled two-bank
# family layouts, 'crprecis' the deterministic linear-counter baseline.
# The fourth discriminates the multi-tenant bank layouts (composite-key
# routing, tenant-major rows — repro.sketch.tenant).
_REGISTRY: Dict[Tuple[str, bool, str, bool], Any] = {}


def spec_axis(spec: SketchSpec) -> str:
    """The registry's layout-family axis of a spec."""
    if spec.backend == "crprecis":
        return "crprecis"
    if spec.variant in FAMILY_VARIANTS:
        return spec.variant
    return "base"


def register_adapter(kind: str, sharded: bool, adapter,
                     axis: str = "base", tenants: bool = False) -> None:
    """Plug a new backend layout into the spec-driven surface."""
    _REGISTRY[(kind, sharded, axis, tenants)] = adapter


def adapter_for(spec: SketchSpec):
    try:
        return _REGISTRY[(spec.kind, spec.shards is not None,
                          spec_axis(spec), spec.tenants is not None)]
    except KeyError:
        raise ValueError(
            f"no adapter registered for kind={spec.kind!r}, "
            f"sharded={spec.shards is not None}, "
            f"axis={spec_axis(spec)!r}, "
            f"tenants={spec.tenants is not None}") from None


register_adapter("frequency", False, _FrequencyAdapter())
register_adapter("frequency", True, _ShardedFrequencyAdapter())
register_adapter("quantile", False, _DyadicAdapter())
register_adapter("quantile", True, _DyadicShardedAdapter())

# the SpaceSaving± family layouts (Double / unbiased SS± + CR-precis)
# live in family.py and register on their own registry axes — imported
# after the registry exists (family.py never imports api at module
# scope, so this is acyclic).
from . import family as _family  # noqa: E402

register_adapter("frequency", False, _family.DoubleAdapter(), axis="double")
register_adapter("frequency", True, _family.DoubleAdapter(), axis="double")
register_adapter("frequency", False, _family.DoubleAdapter(unbiased=True),
                 axis="unbiased")
register_adapter("frequency", True, _family.DoubleAdapter(unbiased=True),
                 axis="unbiased")
register_adapter("frequency", False, _family.CRPrecisAdapter(),
                 axis="crprecis")

# the multi-tenant bank layouts (same acyclic post-registry import):
# base sspm/lazy through TenantAdapter, the family variants through the
# tenant-aware DoubleAdapter — per-tenant rows on BOTH coupled banks.
from . import tenant as _tenant  # noqa: E402

register_adapter("frequency", False, _tenant.TenantAdapter(), tenants=True)
register_adapter("frequency", True, _tenant.TenantAdapter(), tenants=True)
for _sharded in (False, True):
    register_adapter("frequency", _sharded, _family.DoubleAdapter(),
                     axis="double", tenants=True)
    register_adapter("frequency", _sharded,
                     _family.DoubleAdapter(unbiased=True),
                     axis="unbiased", tenants=True)
del _sharded


# ---------------------------------------------------------------------------
# The uniform functional surface
# ---------------------------------------------------------------------------

def make(spec: SketchSpec):
    """Empty state for ``spec`` (a pure pytree; all ops stay functional)."""
    return adapter_for(spec).make(spec)


def update(spec: SketchSpec, state, items, weights=None, *, path=None):
    """Ingest one block of signed weighted updates; returns the new state.

    ``weights=None`` means all-ones (unit inserts).  Concrete (host)
    inputs are validated against the block conventions
    (``validate_block``); traced inputs pass through — jit ``update``
    freely with ``spec`` static.
    """
    if path is not None:
        warnings.warn(
            "api.update(..., path=...) is deprecated; the execution path "
            "is part of the spec — use dataclasses.replace(spec, "
            "backend=...) instead", DeprecationWarning, stacklevel=2)
        spec = dataclasses.replace(spec, backend=path)
    if weights is None:
        weights = np.ones(np.shape(items), np.int32)
    # validate BEFORE any device cast: jnp.asarray under x64-off would
    # silently truncate 64-bit ids, defeating the checks
    validate_block(spec, items, weights)
    if not isinstance(items, jax.Array):     # device arrays pass through
        items = jnp.asarray(np.asarray(items).astype(np.int32))
    if not isinstance(weights, jax.Array):
        weights = jnp.asarray(np.asarray(weights).astype(np.int32))
    return adapter_for(spec).update(spec, state, items, weights)


def query_many(spec: SketchSpec, state, items) -> jax.Array:
    """Estimated frequency per query id (leaf-layer reads for quantile)."""
    return adapter_for(spec).query_many(spec, state,
                                        jnp.asarray(items, jnp.int32))


def query(spec: SketchSpec, state, item) -> jax.Array:
    return query_many(spec, state, jnp.asarray([item], jnp.int32))[0]


def topk(spec: SketchSpec, state, m: int) -> Tuple[jax.Array, jax.Array]:
    """Top-m (ids, counts) heavy hitters by estimated count.

    On ``tenants=T`` specs the ids are COMPOSITE keys (items of
    different tenants are different keys); per-tenant heavy hitters in
    raw item ids come from :func:`tenant_topk`.
    """
    return adapter_for(spec).topk(spec, state, m)


def tenant_topk(spec: SketchSpec, state, tenant,
                m: int) -> Tuple[jax.Array, jax.Array]:
    """ONE tenant's top-m (raw items, counts); never crosses tenants.

    Only meaningful on multi-tenant specs (``tenants=T``): the answer
    reads the tenant's own row slice and nothing else.
    """
    ad = adapter_for(spec)
    if spec.tenants is None or not hasattr(ad, "topk_tenant"):
        raise ValueError(
            f"tenant_topk needs a multi-tenant spec (tenants=T); this spec "
            f"has tenants={spec.tenants}. Use topk for the global answer.")
    return ad.topk_tenant(spec, state, tenant, m)


def rank_many(spec: SketchSpec, state, xs) -> jax.Array:
    """Estimated rank(x) = |{v <= x}| per query (quantile kinds only)."""
    return adapter_for(spec).rank_many(spec, state,
                                       jnp.asarray(xs, jnp.int32))


def rank(spec: SketchSpec, state, x) -> int:
    return int(rank_many(spec, state, jnp.asarray([x], jnp.int32))[0])


def quantile_many(spec: SketchSpec, state, qs) -> jax.Array:
    """Smallest x with rank(x) >= q·|F|₁ per query (quantile kinds only)."""
    return adapter_for(spec).quantile_many(
        spec, state, jnp.asarray(qs, jnp.float32))


def quantile(spec: SketchSpec, state, q: float) -> int:
    return int(quantile_many(spec, state, jnp.asarray([q], jnp.float32))[0])


def merge(spec: SketchSpec, a, b):
    """Mergeable-summaries merge of two same-spec states (cross-host)."""
    return adapter_for(spec).merge(spec, a, b)


def consolidate(spec: SketchSpec, state):
    """Fold a sharded state into its single-host summary (checkpoint
    compaction); identity for unsharded specs."""
    return adapter_for(spec).consolidate(spec, state)


# ---------------------------------------------------------------------------
# Checkpointing: tagged flat dicts, legacy layouts accepted
# ---------------------------------------------------------------------------

def save(spec: SketchSpec, state) -> Dict[str, Any]:
    """Flat numpy dict (npz/checkpoint-safe) with an integer layout tag.

    The unsharded frequency layout is byte-for-byte the historical
    ``stats._SketchBank.state_dict`` layout plus the tag, so checkpoints
    written through this surface load in old readers and vice versa.
    """
    return adapter_for(spec).save(spec, state)


def infer_spec(spec: SketchSpec, d: Dict[str, Any]) -> SketchSpec:
    """Adapt ``spec``'s layout axes (kind, shards) to a checkpoint dict.

    Pre-redesign dicts carry no tag: kind falls back to the presence of
    ``mass`` (quantile banks always track |F|₁), shardedness to the
    ``shards`` key — exactly the discrimination the old
    ``_SketchBank.load_state_dict`` applied.
    """
    known = {LAYOUT_FREQUENCY: "frequency", LAYOUT_QUANTILE: "quantile",
             LAYOUT_DOUBLE: "double/unbiased family",
             LAYOUT_CRPRECIS: "crprecis"}
    tag = int(np.asarray(d["layout"])) if "layout" in d else None
    if tag is not None and tag not in known:
        raise ValueError(
            f"unknown checkpoint layout tag {tag} (known: "
            f"{ {t: n for t, n in known.items()} }); "
            f"the dict is corrupted or written by a newer layout")
    kind = ("quantile" if tag == LAYOUT_QUANTILE or
            (tag is None and "mass" in d) else "frequency")
    raw_shards = d.get("shards")
    n_shards = int(np.asarray(raw_shards)) if raw_shards is not None else 0
    shards = n_shards or None
    changes: Dict[str, Any] = {}
    if kind != spec.kind:
        changes["kind"] = kind
        if kind == "quantile" and spec.bits is None:
            changes["bits"] = int(np.asarray(d["ids"]).shape[-2])
    if shards != spec.shards:
        changes["shards"] = shards
    raw_tenants = d.get("tenants")
    n_tenants = int(np.asarray(raw_tenants)) if raw_tenants is not None else 0
    tenants = (n_tenants or None) if kind == "frequency" else None
    if tenants != spec.tenants:
        changes["tenants"] = tenants
        if spec.tenant_caps is not None:
            # the caps were sized for a different fleet; the restored
            # state carries its own per-row BLOCKED capacity masks, so
            # re-size the spec by the dict's live counters
            changes["tenant_caps"] = None
            changes["k"] = int((np.asarray(d["ids"]) != st.BLOCKED).sum())
        if tenants is not None and spec.bits is None:
            changes["bits"] = int(np.asarray(d["item_bits"]))
    # layout-family axes: the family tag carries which variant wrote it
    # (1 = double, 2 = unbiased); the crprecis tag forces its backend.
    if tag == LAYOUT_DOUBLE:
        want = "unbiased" if int(np.asarray(d.get("family", 1))) == 2 \
            else "double"
        if spec.variant != want:
            changes["variant"] = want
        if spec.backend != "bank":
            changes["backend"] = "bank"
    elif tag == LAYOUT_CRPRECIS:
        if spec.backend != "crprecis":
            changes["backend"] = "crprecis"
        if spec.variant != "sspm":
            changes["variant"] = "sspm"
    else:
        if spec.variant in FAMILY_VARIANTS:
            changes["variant"] = "sspm"
        if spec.backend == "crprecis":
            changes["backend"] = "bank"
    if changes and "backend" not in changes:
        # the stored layout may not support the spec's backend
        probe = dataclasses.replace(spec, **changes, backend="bank")
        if spec.backend not in backends_for(probe.kind, probe.shards,
                                            probe.variant, probe.tenants):
            changes["backend"] = "bank"
    return dataclasses.replace(spec, **changes) if changes else spec


def _validate_checkpoint(spec: SketchSpec, d: Dict[str, Any]) -> None:
    """Reject truncated/corrupted checkpoint dicts BEFORE any state is
    built — ``restore`` either returns a complete state or raises, never
    a half-loaded one.

    Checks: required keys present (``mass`` included for quantile
    kinds), counter fields integer-typed (a float dtype means the dict
    was corrupted or written by something else — casting would silently
    truncate, and NaN poisoning only exists in float arrays), and the
    three counter fields shape-consistent.
    """
    axis = spec_axis(spec)
    if axis == "crprecis":
        # linear counter array: no ids/errors, just counters + moduli
        for key in ("counts", "primes"):
            if key not in d:
                raise ValueError(
                    f"checkpoint dict is missing key {key!r} (truncated "
                    f"write?); a crprecis checkpoint needs counts + primes")
            if np.asarray(d[key]).dtype.kind not in "iu":
                raise ValueError(
                    f"checkpoint field {key!r} has dtype "
                    f"{np.asarray(d[key]).dtype}; crprecis counters and "
                    f"moduli are integer arrays")
        return
    required = ["ids", "counts", "errors"]
    if spec.kind == "quantile":
        required.append("mass")
    triples = [("ids", "counts", "errors")]
    if axis in FAMILY_VARIANTS:
        # the delete-side bank rides along under _del suffixes
        required += ["ids_del", "counts_del", "errors_del"]
        triples.append(("ids_del", "counts_del", "errors_del"))
    missing = [k for k in required if k not in d]
    if missing:
        raise ValueError(
            f"checkpoint dict is missing key(s) {missing} (truncated "
            f"write?); a {spec.kind!r} checkpoint needs {required}")
    for keys in triples:
        shapes = {}
        for key in keys:
            arr = np.asarray(d[key])
            if arr.dtype.kind not in "iu":
                raise ValueError(
                    f"checkpoint field {key!r} has dtype {arr.dtype}; sketch "
                    f"counters are integer arrays — refusing to cast a "
                    f"float/object dtype silently (corrupted or foreign "
                    f"checkpoint)")
            shapes[key] = arr.shape
        if len(set(shapes.values())) != 1:
            raise ValueError(
                f"checkpoint counter fields disagree in shape: {shapes}; the "
                f"dict is truncated or mixes two checkpoints")
    if spec.kind == "quantile":
        mass = np.asarray(d["mass"])
        if mass.dtype.kind not in "iu" or mass.size != 1:
            raise ValueError(
                f"checkpoint field 'mass' must be an integer scalar "
                f"(|F|₁), got dtype {mass.dtype}, shape {mass.shape}")


def restore(spec: SketchSpec, d: Dict[str, Any]):
    """State from a ``save`` dict — or a pre-redesign stats layout.

    The spec must match the dict's layout; use ``infer_spec`` first when
    restoring checkpoints whose shard count / kind may have drifted from
    the configured spec (that is what ``StreamSession.load`` does).
    Truncated or corrupted dicts (missing keys, float dtypes, mismatched
    shapes, unknown layout tags) raise ``ValueError`` before any state
    is constructed — never a half-loaded state.
    """
    inferred = infer_spec(spec, d)
    if (inferred.kind, inferred.shards, spec_axis(inferred),
            inferred.tenants) != \
            (spec.kind, spec.shards, spec_axis(spec), spec.tenants):
        raise ValueError(
            f"checkpoint layout is kind={inferred.kind!r}, "
            f"shards={inferred.shards}, axis={spec_axis(inferred)!r}, "
            f"tenants={inferred.tenants}, but the spec says "
            f"kind={spec.kind!r}, shards={spec.shards}, "
            f"axis={spec_axis(spec)!r}, tenants={spec.tenants}; restore "
            f"through infer_spec(spec, d) (StreamSession.load does)")
    _validate_checkpoint(spec, d)
    return adapter_for(spec).restore(spec, d)


# ---------------------------------------------------------------------------
# Deprecation plumbing shared by the per-client shims
# ---------------------------------------------------------------------------

def deprecated_alias(old: str, new: str, fn):
    """Wrap ``fn`` so calls through the OLD spelling warn once per name.

    The wrapper forwards verbatim (``__wrapped__`` pins identity in
    tests) — old call sites keep the same objects and semantics, they
    just learn where the one canonical spelling lives now.
    """
    warned = []

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if not warned:
            warned.append(True)
            warnings.warn(
                f"{old} is deprecated; use {new} (the spec-driven "
                f"repro.sketch.api surface)", DeprecationWarning,
                stacklevel=2)
        return fn(*args, **kwargs)

    return wrapper


__all__ = [
    "KINDS",
    "VARIANTS",
    "FAMILY_VARIANTS",
    "BACKENDS",
    "LAYOUT_FREQUENCY",
    "LAYOUT_QUANTILE",
    "LAYOUT_DOUBLE",
    "LAYOUT_CRPRECIS",
    "SketchSpec",
    "backends_for",
    "variants_for",
    "spec_axis",
    "validate_block",
    "register_adapter",
    "adapter_for",
    "make",
    "update",
    "query",
    "query_many",
    "topk",
    "tenant_topk",
    "rank",
    "rank_many",
    "quantile",
    "quantile_many",
    "merge",
    "consolidate",
    "save",
    "infer_spec",
    "restore",
    "deprecated_alias",
]
