"""Block algorithms of the JAX SpaceSaving± sketch.

Top algorithm layer of the sketch package (DESIGN.md §9): single-update
semantics (``apply_update``), the exact sequential scan
(``process_stream``), and the **two-phase monitored-first** block update
(DESIGN.md §3): updates to already-monitored items commute, so after
segment-aggregation all monitored deltas land in one vectorized
scatter-add (phase 1). The residual is further decomposed (DESIGN.md
§3.2) into three exactly-vectorizable-or-cheap pieces, processed in the
canonical order *inserts before unmonitored deletions*:

  1.5   **bulk empty fill** — sequential semantics always place new
        items into empty slots (in flat-index order) before any
        eviction, so the first ``min(#empties, #residual inserts)``
        inserts are one scatter (bit-identical to the sequential
        recurrence);
  1.75  **unit-weight eviction water-fill** — with w = 1 the sequential
        "evict argmin, set min+1" recurrence is a water-filling
        process: the evicted values are exactly the m smallest of
        {count_j + t : t >= 0} with (value, slot-index) tie-breaking,
        so final counts/errors/ids come from a binary-searched water
        level plus rank arithmetic — vectorized AND bit-identical to
        looping (see ``phases.waterfill_unit_inserts``);
  2a    **eviction loop** — only residual inserts with net weight != 1
        still run the sequential recurrence, each step an O(R + LANES)
        two-level row-tournament reduction (per-row min/max maintained
        incrementally + an (R,)-wide final reduce) instead of a flat
        O(k) argmin/argmax;
  2b    **bulk deletion spread** — unmonitored SS± deletions don't
        depend on the deleted item's identity and greedy max-error
        spreading commutes, so all residual deletions collapse into ONE
        spread of their summed weight (iterations = slots drained, not
        deleted uniques).

All updates are *branchless* (jnp.where selects) so they vectorize on the
VPU and vmap across many sketches (per-expert / per-layer / per-shard).

Semantics: identical to the reference `repro.core.spacesaving` classes up
to argmin/argmax tie-breaking (reference heaps break ties by heap order;
here ties break to the lowest flat index). All paper guarantees
(Thms 2/4/5) are tie-break independent and are property-tested for this
implementation directly.

``variant``: 1 = Lazy SS± (Alg 3), 2 = SS± (Alg 4). Insertions (Alg 1) are
shared. Weighted updates follow the standard weighted SpaceSaving
extension (replacement absorbs the whole weight; deletion of unmonitored
mass spreads over max-error items, each absorbing up to its error).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .phases import (
    _stable_partition_perm,
    fill_empty_slots,
    pad_rows,
    residual_phase,
    segment_nets,
    waterfill_unit_inserts,
)
from .state import (EMPTY, VARIANT_LAZY, VARIANT_SSPM, SketchState, _INT_MAX,
                    sat_add)


# ---------------------------------------------------------------------------
# Single weighted update (branchless)
# ---------------------------------------------------------------------------

def _insert(state: SketchState, item: jax.Array, w: jax.Array) -> SketchState:
    ids, counts, errors = state
    # sentinel slots (negative ids) never count as monitored
    eq = (ids == item) & (ids >= 0)
    monitored = eq.any()
    slot_mon = jnp.argmax(eq)

    empty = ids == EMPTY
    has_empty = empty.any()
    slot_empty = jnp.argmax(empty)

    jmin = jnp.argmin(jnp.where(empty, _INT_MAX, counts))
    min_count = counts[jmin]

    sel = jnp.where(monitored, slot_mon, jnp.where(has_empty, slot_empty, jmin))
    new_count = jnp.where(
        monitored, sat_add(counts[slot_mon], w),
        jnp.where(has_empty, w, sat_add(min_count, w))
    )
    new_error = jnp.where(
        monitored, errors[slot_mon], jnp.where(has_empty, 0, min_count)
    )
    return SketchState(
        ids=ids.at[sel].set(item),
        counts=counts.at[sel].set(new_count),
        errors=errors.at[sel].set(new_error),
    )


def _delete(
    state: SketchState, item: jax.Array, w: jax.Array, variant: int
) -> SketchState:
    ids, counts, errors = state
    # sentinel slots (negative ids) never count as monitored
    eq = (ids == item) & (ids >= 0)
    monitored = eq.any()
    slot_mon = jnp.argmax(eq)

    # monitored: subtract w at the monitored slot
    counts_mon = counts.at[slot_mon].add(jnp.where(monitored, -w, 0))

    if variant == VARIANT_LAZY:
        return SketchState(ids, counts_mon, errors)

    # SS± (Alg 4): unmonitored deletion decrements (count, error) of the
    # max-error item; weight spreads across items, each absorbing <= error_j.
    def spread(carry):
        rem, cnts, errs = carry
        jerr = jnp.argmax(errs)
        max_err = errs[jerr]
        d = jnp.minimum(rem, max_err)
        return (
            rem - d,
            cnts.at[jerr].add(-d),
            errs.at[jerr].add(-d),
        )

    def cond(carry):
        rem, _, errs = carry
        return (rem > 0) & (errs.max() > 0)

    rem0 = jnp.where(monitored, 0, w)
    _, counts_un, errors_un = jax.lax.while_loop(
        cond, lambda c: spread(c), (rem0, counts_mon, errors)
    )
    return SketchState(ids, counts_un, errors_un)


def apply_update(
    state: SketchState, item: jax.Array, weight: jax.Array, variant: int = VARIANT_SSPM
) -> SketchState:
    """One signed, weighted update. weight > 0 insert, < 0 delete, 0 no-op."""
    ins = _insert(state, item, jnp.maximum(weight, 0))
    dele = _delete(state, item, jnp.maximum(-weight, 0), variant)
    pick = weight > 0
    return jax.tree.map(
        lambda a, b: jnp.where(pick, a, b), ins, dele
    )


# ---------------------------------------------------------------------------
# Sequential scan paths (oracle + serial block baseline share one body)
# ---------------------------------------------------------------------------

def _apply_update_scan(
    state: SketchState,
    items: jax.Array,
    weights: jax.Array,
    variant: int,
    skip_sentinels: bool,
) -> SketchState:
    """The per-item ``apply_update`` scan shared by ``process_stream`` and
    ``block_update_serial`` (previously duplicated in both).

    ``skip_sentinels``: the aggregated-uniques path carries EMPTY/zero-net
    padding entries that must leave the state untouched; the raw-stream
    oracle path applies every entry verbatim.
    """

    def step(st, xw):
        item, w = xw
        new = apply_update(st, item, w, variant)
        if skip_sentinels:
            skip = (item == EMPTY) | (w == 0)
            new = jax.tree.map(lambda a, b: jnp.where(skip, b, a), new, st)
        return new, None

    state, _ = jax.lax.scan(
        step, state, (items.astype(jnp.int32), weights.astype(jnp.int32))
    )
    return state


@functools.partial(jax.jit, static_argnames=("variant",))
def process_stream(
    state: SketchState,
    items: jax.Array,
    weights: jax.Array,
    variant: int = VARIANT_SSPM,
) -> SketchState:
    """Exact sequential semantics via lax.scan (the oracle path)."""
    return _apply_update_scan(state, items, weights, variant,
                              skip_sentinels=False)


# ---------------------------------------------------------------------------
# Block aggregation + phase-1 partition against the monitored set
# ---------------------------------------------------------------------------

def _aggregate_block(items: jax.Array, weights: jax.Array,
                     assume_sorted: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Net weight per unique item in the block (sort + prefix sums).

    Returns (uids, net) of the same length; padding slots have uid == EMPTY
    and net == 0. Net weight order: uniques appear in ascending id order.
    ``assume_sorted`` skips the argsort when the caller already provides
    ascending items (the dyadic bank sorts the raw block once — every
    per-layer ``x >> l`` view stays sorted because right-shift is
    monotonic; the sharded router shares one sort the same way).

    Per-unique sums come from the shared ``phases.segment_nets`` prefix
    trick (segment_sum scatters serialize on CPU).
    """
    B = items.shape[0]
    if assume_sorted:
        s = items.astype(jnp.int32)
        w = weights.astype(jnp.int32)
    else:
        order = jnp.argsort(items)
        s = items[order].astype(jnp.int32)
        w = weights[order].astype(jnp.int32)
    idx = jnp.arange(B, dtype=jnp.int32)
    head, net_h = segment_nets(s[None, :], w[None, :])
    head, net_h = head[0], net_h[0]  # net valid at head positions
    perm = _stable_partition_perm(jnp.where(head, 0, 1))
    n_seg = head.sum()
    uids = jnp.where(idx < n_seg, s[perm], EMPTY)
    net = jnp.where(idx < n_seg, net_h[perm], 0)
    return uids, net


def _valid_mask(uids: jax.Array, net: jax.Array) -> jax.Array:
    """Aggregated entries that carry real work: non-sentinel id, nonzero net."""
    return (uids >= 0) & (net != 0)


class BlockPartition(NamedTuple):
    """Phase-1 output: monitored deltas applied, residual split by sign."""

    counts1: jax.Array  # (k,) counts after the commuting monitored scatter
    r_uids: jax.Array   # residual *insert* uids compacted to the front
    r_net: jax.Array    # net weights aligned with r_uids
    n_ins: jax.Array    # number of residual insert uniques (dynamic)
    w_del: jax.Array    # summed unmonitored deletion weight (0 for lazy)
    n_res: jax.Array    # all residual uniques incl. deletes (diagnostics)
    n_mon: jax.Array    # monitored uniques (diagnostics)


def partition_block(state: SketchState, uids: jax.Array, net: jax.Array,
                    variant: int = VARIANT_SSPM) -> BlockPartition:
    """Phase-1 split of an aggregated block against the monitored set.

    Monitored membership runs in the cheap direction: the k slot ids are
    binary-searched into the B sorted block uniques (k << B queries), so
    the monitored delta application is a pure GATHER per slot — no
    (U, k) materialization and no B-wide scatter-add (CPU XLA serializes
    scatters). Residual inserts are compacted to the front of
    (r_uids, r_net) in ascending id order; residual deletions are not
    enumerated at all — unmonitored spreading is item-agnostic, so only
    their summed weight ``w_del`` survives (see the module docstring).
    """
    B = uids.shape[0]
    valid = _valid_mask(uids, net)
    # compacted uids are ascending uniques then EMPTY padding; remap the
    # padding to INT_MAX to keep the array sorted for searchsorted.
    usearch = jnp.where(uids >= 0, uids, _INT_MAX)
    pos = jnp.clip(jnp.searchsorted(usearch, state.ids), 0, B - 1)
    # usearch is non-negative by construction, so sentinel slots could
    # never match anyway — the explicit guard keeps the invariant local
    # (and machine-checkable) instead of relying on the remap above.
    match = (usearch[pos] == state.ids) & (state.ids >= 0)
    # Monitored deltas commute (insert: count += w; delete: count -= w; ids
    # and errors untouched) — one gather applies them all at once,
    # saturating at ±INT_MAX instead of wrapping.
    counts1 = sat_add(state.counts, jnp.where(match, net[pos], 0))
    monitored = (
        jnp.zeros((B,), bool)
        .at[jnp.where(match, pos, B)]
        .set(True, mode="drop")
    )
    res_ins = valid & ~monitored & (net > 0)
    if variant == VARIANT_LAZY:
        # Lazy SS± drops unmonitored deletions entirely (Alg 3).
        w_del = jnp.int32(0)
        n_res = res_ins.sum()
    else:
        res_del = valid & ~monitored & (net < 0)
        w_del = (-jnp.where(res_del, net, 0)).sum()
        n_res = res_ins.sum() + res_del.sum()
    perm = _stable_partition_perm(jnp.where(res_ins, 0, 1))
    n_ins = res_ins.sum()
    idx = jnp.arange(B)
    r_uids = jnp.where(idx < n_ins, uids[perm], 0)
    r_net = jnp.where(idx < n_ins, net[perm], 0)
    return BlockPartition(counts1, r_uids, r_net,
                          n_ins, w_del, n_res, (match & valid[pos]).sum())


def _phase1(state: SketchState, items: jax.Array, weights: jax.Array,
            variant: int, assume_sorted: bool = False):
    """Phases 1-1.75 — everything vectorizable, shared by the pure-JAX
    and Pallas block paths so they stay bit-identical.

    Aggregate, apply monitored deltas, bulk-fill empties, water-fill
    unit-weight evictions. Returns the updated flat arrays plus the
    kernel-bound residual-loop inputs: the re-grouped residual array
    (uids, net) laid out [unit inserts | non-unit inserts | rest] with
    the loop's [start, end) range covering the non-unit inserts, and the
    summed unmonitored deletion weight.
    """
    uids, net = _aggregate_block(items, weights, assume_sorted)
    part = partition_block(state, uids, net, variant)
    ids1, cnt1, err1, i0 = fill_empty_slots(
        state.ids, part.counts1, state.errors, part.r_uids, part.r_net,
        part.n_ins)
    idx = jnp.arange(part.r_uids.shape[0])
    remaining = (idx >= i0) & (idx < part.n_ins)
    unit = remaining & (part.r_net == 1)
    nonunit = remaining & (part.r_net != 1)
    # one cheap key-sort groups [units | non-units | rest]
    perm = _stable_partition_perm(jnp.where(unit, 0, jnp.where(nonunit, 1, 2)))
    r_uids = part.r_uids[perm]
    r_net = part.r_net[perm]
    m_u = unit.sum()
    ids1, cnt1, err1 = waterfill_unit_inserts(ids1, cnt1, err1, r_uids, m_u)
    return (ids1, cnt1, err1, r_uids, r_net, m_u, m_u + nonunit.sum(),
            part.w_del)


# ---------------------------------------------------------------------------
# Two-phase block update: monitored-first scatter + residual tournament loop
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("variant", "assume_sorted"))
def block_update(
    state: SketchState,
    items: jax.Array,
    weights: jax.Array,
    variant: int = VARIANT_SSPM,
    assume_sorted: bool = False,
) -> SketchState:
    """Two-phase block (weighted) update — the production TPU path.

    Segment-aggregate, scatter all monitored deltas at once (they commute:
    bit-identical to sequential processing for monitored-only blocks),
    bulk-fill empty slots, then run the sequential recurrence only over
    the leftover residual inserts with O(R + LANES) tournament steps and
    drain all unmonitored deletion weight in one bulk spread. Guarantees
    are those of weighted SpaceSaving± (module docstring); equivalence to
    unit-update processing holds up to within-block reordering (inserts
    are canonically processed before unmonitored deletions), which the
    bounded-deletion model's guarantees (Thms 2/4/5) are stable to.
    """
    k = state.ids.shape[0]
    ids1, cnt1, err1, r_uids, r_net, nu_start, nu_end, w_del = _phase1(
        state, items, weights, variant, assume_sorted)
    ids2, cnt2, err2 = pad_rows(ids1, cnt1, err1)
    ids2, cnt2, err2 = residual_phase(
        ids2, cnt2, err2, r_uids, r_net, nu_start, nu_end, w_del, variant)
    return SketchState(
        ids=ids2.reshape(-1)[:k],
        counts=cnt2.reshape(-1)[:k],
        errors=err2.reshape(-1)[:k],
    )


@functools.partial(jax.jit, static_argnames=("variant",))
def block_update_serial(
    state: SketchState,
    items: jax.Array,
    weights: jax.Array,
    variant: int = VARIANT_SSPM,
) -> SketchState:
    """Pre-two-phase baseline: serial scan over the aggregated uniques.

    Kept for A/B benchmarking (bench_kernels reports the speedup) and as a
    semantics cross-check in tests. Same aggregation, same per-unique
    weighted-apply (one scan body shared with ``process_stream``) — just
    O(U · k) with no inter-update parallelism.
    """
    uids, net = _aggregate_block(items, weights)
    return _apply_update_scan(state, uids, net, variant, skip_sentinels=True)


@functools.partial(jax.jit, static_argnames=("variant", "assume_sorted"))
def block_update_batched(
    states: SketchState,
    items: jax.Array,
    weights: jax.Array,
    variant: int = VARIANT_SSPM,
    assume_sorted: bool = False,
) -> SketchState:
    """vmap'd two-phase update over stacked sketches.

    states: SketchState with leading batch axis (E, k); items/weights:
    (E, B). One launch for a per-expert / per-layer / per-shard sketch
    bank (the configs/ model zoo stacks per-layer sketches this way; the
    hash-sharded bank in ``repro.sketch.sharded`` stacks per-shard ones).
    ``assume_sorted``: every row of ``items`` is already ascending (the
    dyadic bank sorts the raw block once; monotone shifts keep every
    layer sorted; the sharded router broadcasts one sorted block) —
    skips E argsorts.
    """
    return jax.vmap(
        lambda s, i, w: block_update(s, i, w, variant, assume_sorted)
    )(states, items, weights)


def block_partition_stats(state: SketchState, items: jax.Array,
                          weights: jax.Array, variant: int = VARIANT_SSPM):
    """Diagnostics: (n_unique, n_monitored, n_residual) for one block.

    ``n_residual / n_unique`` is the serial fraction of the two-phase
    update — the quantity bench_kernels reports per distribution. (Since
    the bulk empty-fill and bulk deletion spread landed, the serial
    eviction loop covers only part of n_residual; this stays the
    conservative upper bound.)
    """
    uids, net = _aggregate_block(items, weights)
    part = partition_block(state, uids, net, variant)
    return int(_valid_mask(uids, net).sum()), int(part.n_mon), int(part.n_res)


__all__ = [
    "apply_update",
    "process_stream",
    "BlockPartition",
    "partition_block",
    "block_update",
    "block_update_serial",
    "block_update_batched",
    "block_partition_stats",
]
