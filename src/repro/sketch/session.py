"""StreamSession: the stateful host-side companion of the sketch API.

Every consumer of the sketch package used to hand-roll the same glue:
``stats._SketchBank`` chunked-and-padded batches to a fixed block,
``examples/quantile_monitor.py`` buffered observations and scheduled
sliding-window expiry deletions, and the benches re-spelled the
pad-and-feed loop per script.  :class:`StreamSession` owns that
machinery once, on top of the functional ``repro.sketch.api`` surface:

  * **block buffering** — ``observe``/``extend`` accumulate updates
    host-side (numpy, no per-item python lists for array input) and
    flush full fixed-size blocks, zero-weight padding the tail, so the
    jitted ingest traces ONE (spec, block) shape;
  * **cached jitted ingest** — one compiled update per (spec, block),
    shared across sessions via a process-lifetime cache keyed on the
    hashable spec (intentionally unbounded: evicting would silently
    retrace live sessions); state buffers are donated on accelerators
    (the CPU backend cannot reuse donated buffers, so donation is
    skipped there to avoid the per-call warning);
  * **windowed deletion scheduling** — the paper's bounded-deletion
    regime by construction: ``push`` expires whole batches after
    ``window`` pushes (the stats trackers), ``observe`` expires
    individual items after ``window`` observations (the quantile
    monitor); expiries re-ingest with negated weights and the
    insertion/deletion totals track the empirical alpha;
  * **queries / merge / checkpointing** — thin delegations to the api
    (each flushes pending updates first), with ``save``/``load``
    speaking the tagged checkpoint dicts *and* the pre-redesign stats
    layouts (``api.infer_spec`` adapts kind/shards to what the dict
    actually holds); ``save(include_schedule=True)`` additionally
    serializes the scheduling state (buffer, expiry FIFOs, counters,
    block cursor) so a crash/resume round-trip loses and double-counts
    nothing;
  * **fault tolerance hooks** — an optional block ``replay`` log (the
    last N ingested blocks, keyed by a monotone block sequence number)
    feeds ``repro.sketch.elastic.recover_session``; an optional
    ``fault_plan`` (``repro.sketch.faults.FaultPlan``) injects
    drop/duplicate/corrupt/delay faults at the block boundary — the
    replay log records the INTENDED block before injection, so recovery
    restores the truth; an optional ``monitor``
    (``repro.train.straggler.StragglerMonitor``) observes per-shard
    flush timings (inflated by injected delays) so a slow shard walks
    the straggler → flag → recovery path.

Ingest through a session is bit-identical to calling ``api.update``
(and therefore the direct engine/client spellings) on the same padded
blocks — the session adds scheduling, never semantics.  Measured
overhead at the headline bench cells is <5% vs the raw fused engine
call (BENCH_sharded.json / BENCH_quantiles.json ``session_overhead``).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

import jax

from . import api
from .api import SketchSpec


def ingest_cache_spec(spec: SketchSpec) -> SketchSpec:
    """Normalize a spec to its compiled-ingest cache identity.

    The jitted ingest's trace depends on the spec only through what the
    adapter's ``update`` actually reads: kind / variant / backend / bits
    / shards (+ the state SHAPES, which jit keys on by itself). The
    tenant axis deliberately keeps the update path tenant-count-blind —
    adapters derive the tenant count from the state's leading axis — so
    a thousand per-tenant layouts that agree on those fields share ONE
    cache entry instead of growing the process-lifetime cache without
    bound. Tenant specs therefore collapse onto a ``tenants=1``
    canonical form (capacity folded back into a plain ``k``); non-tenant
    specs are their own identity.
    """
    if spec.tenants is None:
        return spec
    changes = {"tenants": 1, "tenant_caps": None}
    if spec.tenant_caps is not None:
        changes["k"] = int(sum(spec.tenant_caps))
    return dataclasses.replace(spec, **changes)


@functools.lru_cache(maxsize=None)
def _ingest_fn_cached(spec: SketchSpec, block: int, donate: bool = True):
    def ingest(state, items, weights):
        return api.adapter_for(spec).update(spec, state, items, weights)

    # platform-resolved: donation is on iff an accelerator is attached
    # (repro.platform.donate_state_buffers; DESIGN.md §14 on why CPU
    # keeps it off). Donation changes buffer reuse only, never results —
    # pinned by tests/test_platform.py.
    from repro.platform import donate_state_buffers

    donate_args = (0,) if donate and donate_state_buffers() else ()
    return jax.jit(ingest, donate_argnums=donate_args)


def _ingest_fn(spec: SketchSpec, block: int, donate: bool = True):
    """The compiled (state, items, weights) -> state ingest for one
    (spec, block, donate) cell — cached for the process lifetime so
    every session (and bench) of that cell shares one trace (unbounded
    on purpose: an eviction would silently retrace a live session).
    Tenant specs are normalized first (:func:`ingest_cache_spec`) so the
    cache stays bounded by LAYOUTS, not by tenant populations.

    ``donate=True`` donates the state buffers on accelerators (the CPU
    backend cannot reuse donated buffers, so donation is skipped there):
    ingest then consumes the previous state, and any reference a caller
    captured before the update dies with it.  Callers that EXPOSE their
    state to consumers (the stats trackers' public ``.state``) pass
    ``donate=False`` to keep captured references valid, matching the
    pre-redesign behavior."""
    return _ingest_fn_cached(ingest_cache_spec(spec), int(block), donate)


def ingest_cache_stats() -> Dict[str, int]:
    """Cache-accounting hook for benches and tests: how many compiled
    ingest entries exist (``entries``) and the lru hit/miss counters.
    ``benchmarks/bench_service.py`` asserts one-compile-per-layout with
    the ``entries`` delta across a multi-tenant run."""
    info = _ingest_fn_cached.cache_info()
    return {"entries": int(info.currsize), "hits": int(info.hits),
            "misses": int(info.misses)}


class StreamSession:
    """Stateful streaming front-end over one :class:`SketchSpec`.

    ``block``: fixed ingest block length (one compilation per spec).
    ``window``: optional bounded-deletion horizon — in *pushes* for the
    batch path (``push``), in *observations* for the item path
    (``observe``).  ``state``: resume from an existing backend state
    (e.g. a restored checkpoint) instead of an empty one.
    ``replay``: keep the last N ingested blocks (sequence-numbered, as
    ingested — insertions AND expiry deletions) for
    ``elastic.recover_session``; size it to at least the checkpoint
    cadence in blocks.  ``fault_plan``: a ``faults.FaultPlan`` injected
    at the block boundary (sharded specs only).  ``monitor``: a
    ``StragglerMonitor`` observing per-shard flush timings.
    """

    def __init__(self, spec: SketchSpec, block: int = 8192,
                 window: Optional[int] = None, state=None,
                 donate: bool = True, replay: int = 0,
                 fault_plan=None, monitor=None):
        if block < 2:
            raise ValueError(f"block must be >= 2, got {block}")
        if fault_plan is not None and spec.shards is None:
            raise ValueError(
                "fault_plan injects shard-granular faults; the spec must "
                "be sharded (shards=S)")
        self.spec = spec
        self.block = int(block)
        self.window = window
        self.donate = donate
        self.state = state if state is not None else api.make(spec)
        # resolve the cached compiled ingest ONCE — ingest_block stays a
        # plain dispatch (the <5% overhead budget of DESIGN.md §11)
        self._compiled = _ingest_fn(spec, self.block, donate)
        self.insertions = 0
        self.deletions = 0
        # positive mass validated into this session so far — the
        # prior_mass bound api.validate_block holds each new block
        # against (a counter can never exceed it, so per-item nets are
        # rejected before they could carry one past int32). A caller-
        # provided resumed ``state`` starts at 0: its history is
        # unknown, so the bound is best-effort until restored by the
        # caller (``session.ingested_mass = ...`` after a checkpoint
        # load).
        self.ingested_mass = 0
        # resize bound widening, accumulated by elastic.reshard_session
        self.error_slack = 0
        # buffered (items, weights) fragments awaiting a flush
        self._buf_i: List[np.ndarray] = []
        self._buf_w: List[np.ndarray] = []
        self._buf_n = 0
        # windowed-deletion queues (batch- and item-granularity). Batch
        # FIFOs are keyed per tenant (None = the classic single-stream
        # schedule) so a multi-tenant service expires each tenant's
        # batches on that tenant's OWN horizon; the None deque is
        # created eagerly because the stats trackers alias it through
        # the ``batch_fifo`` property.
        self._batch_fifos: Dict[Optional[int],
                                Deque[Tuple[np.ndarray, np.ndarray]]] = {
            None: collections.deque()}
        self._item_fifo: Deque[Tuple[int, int]] = collections.deque()
        # fault-tolerance machinery (all inert by default; deque with
        # maxlen=0 silently retains nothing, so the hot path below can
        # append unconditionally only when replay > 0)
        self.replay = int(replay)
        self._seq = 0  # blocks ingested so far; block i carries seq i
        self._replay: Deque[Tuple[int, np.ndarray, np.ndarray]] = (
            collections.deque(maxlen=max(self.replay, 0)))
        self.fault_plan = fault_plan
        self.monitor = monitor
        self._deferred = {}  # due seq -> [(items, weights)] delayed slices

    @property
    def replay_log(self) -> Tuple[Tuple[int, np.ndarray, np.ndarray], ...]:
        """The retained (seq, items, weights) blocks, oldest first."""
        return tuple(self._replay)

    # -- low-level ingest --------------------------------------------------

    def ingest_block(self, items, weights) -> None:
        """Feed ONE exactly block-sized, already-padded block (hot path).

        No buffering, no conversions — jit canonicalizes numpy/jax
        array operands itself (a host ``jnp.asarray`` here costs ~30µs
        per operand for nothing). This is the call the session-overhead
        bench races against the raw engine launch.

        The replay log records the block BEFORE fault injection: faults
        corrupt the live state, never the recovery truth.
        """
        self._seq += 1
        if self.replay:
            self._replay.append(
                (self._seq, np.asarray(items), np.asarray(weights)))
        if self.fault_plan is None and self.monitor is None:
            self.state = self._compiled(self.state, items, weights)
            return
        self._ingest_faulty(self._seq, items, weights)

    def _ingest_faulty(self, seq: int, items, weights) -> None:
        """Fault-injected / monitored spelling of one block ingest.

        Delay faults land their shard's slice at its due block, so even
        a faulted run ingests every observation exactly once (only
        drop/corrupt lose data — that is their point).
        """
        from . import faults as flt

        shards = self.spec.shards or 1
        # delayed slices that came due re-deliver BEFORE the new block
        for due in sorted(k for k in self._deferred if k <= seq):
            for di, dw in self._deferred.pop(due):
                self.state = self._compiled(self.state, di, dw)
        delay_s = {}
        if self.fault_plan is not None:
            out = flt.inject(self.fault_plan, seq, shards,
                             np.asarray(items), np.asarray(weights))
            delay_s = out.delay_s
            primary, extra = out.blocks[0], out.blocks[1:]
            dt = self._timed_ingest(*primary)
            for bi, bw in extra:
                self.state = self._compiled(self.state, bi, bw)
            for due, di, dw in out.deferred:
                self._deferred.setdefault(due, []).append((di, dw))
            if out.poison_rows:
                self.state = flt.poison_rows(self.state, out.poison_rows)
        else:
            dt = self._timed_ingest(items, weights)
        if self.monitor is not None:
            # per-shard timing: every host reports the primary block's
            # wall time (injection overhead — re-deliveries, poisoning —
            # is harness bookkeeping, not a host's step), and a delayed
            # shard's host reports the injected slowdown on top
            for r in range(shards):
                self.monitor.observe(r, dt + delay_s.get(r, 0.0))

    def _timed_ingest(self, items, weights) -> float:
        """One compiled ingest, timed to completion when a monitor needs
        the wall time (block_until_ready costs pipelining, so plain
        fault-injected runs skip it)."""
        t0 = time.perf_counter()
        self.state = self._compiled(self.state, items, weights)
        if self.monitor is not None:
            jax.block_until_ready(self.state)
        return time.perf_counter() - t0

    def ingest(self, items, weights) -> None:
        """Validate, chunk to the session block, pad, and ingest now.

        Validation runs on the RAW arrays (casting first would wrap
        64-bit ids / truncate floats silently, defeating the checks);
        the int32 cast happens after it proves lossless.
        """
        items = np.asarray(items).ravel()
        weights = np.asarray(weights).ravel()
        self.ingested_mass += api.validate_block(
            self.spec, items, weights, prior_mass=self.ingested_mass)
        items = items.astype(np.int32)
        weights = weights.astype(np.int32)
        for s in range(0, len(items), self.block):
            ci = items[s:s + self.block]
            cw = weights[s:s + self.block]
            pad = self.block - len(ci)
            if pad:
                ci = np.pad(ci, (0, pad))  # weight-0 tail = padding
                cw = np.pad(cw, (0, pad))
            self.ingest_block(ci, cw)

    # -- buffered streaming ------------------------------------------------

    def extend(self, items, weights=None) -> None:
        """Buffer a fragment of signed weighted updates; auto-flush full
        blocks. ``weights=None`` = unit inserts.

        As in ``ingest``: validate raw, cast after (a pre-cast would
        silently wrap 64-bit ids and truncate float weights).
        """
        items = np.asarray(items).ravel()
        if weights is None:
            weights = np.ones(len(items), np.int32)
        else:
            weights = np.asarray(weights).ravel()
        self.ingested_mass += api.validate_block(
            self.spec, items, weights, prior_mass=self.ingested_mass)
        self._append(items.astype(np.int32), weights.astype(np.int32))

    def _append(self, items: np.ndarray, weights: np.ndarray) -> None:
        """Pre-validated int32 fragments -> buffer, auto-flushing."""
        self._buf_i.append(items)
        self._buf_w.append(weights)
        self._buf_n += len(items)
        if self._buf_n >= self.block:
            self._drain(keep_partial=True)

    def observe(self, item: int, weight: int = 1) -> None:
        """One observation; with ``window`` set, expire the observation
        that falls off the horizon (bounded deletion).

        Validates the scalar inline (the full ``validate_block`` per
        single item would dominate this path) and BEFORE touching any
        session state, so a rejected observation never poisons the
        expiry FIFO or the insertion totals.
        """
        item = int(item)
        weight = int(weight)
        if item < 0:
            raise ValueError(
                f"negative item id {item}: ids must be >= 0 (negative ids "
                f"are the EMPTY/BLOCKED sentinels)")
        if self.spec.kind == "quantile" and item >= (1 << self.spec.bits):
            raise ValueError(
                f"item {item} is outside the dyadic universe "
                f"[0, 2^{self.spec.bits}); raise SketchSpec.bits or bucket "
                f"ids before ingest")
        int32_max = int(np.iinfo(np.int32).max)
        if abs(weight) > int32_max:
            raise ValueError(
                f"weight {weight} does not fit int32 (the device-side "
                f"count dtype)")
        if weight > 0 and self.ingested_mass + weight > int32_max:
            raise ValueError(
                f"observation of weight {weight} on a session already "
                f"holding {self.ingested_mass} positive mass could carry "
                f"a counter past int32 max ({int32_max}); rescale or "
                f"checkpoint-and-reset the session")
        expire = (self.window is not None
                  and len(self._item_fifo) >= self.window)
        if expire:
            old_i, old_w = self._item_fifo[0]
            frag_i = np.asarray([item, old_i], np.int32)
            frag_w = np.asarray([weight, -old_w], np.int32)
        else:
            frag_i = np.asarray([item], np.int32)
            frag_w = np.asarray([weight], np.int32)
        self._append(frag_i, frag_w)
        self.insertions += weight
        if weight > 0:
            self.ingested_mass += weight
        if self.window is not None:
            self._item_fifo.append((item, weight))
            if expire:
                self._item_fifo.popleft()
                self.deletions += old_w

    def flush(self) -> None:
        """Ingest everything buffered (padding the final partial block),
        then deliver any still-pending delayed fault slices.

        Without the second step a delay fault near the end of the stream
        would silently drop its slice (nothing arrives with
        ``seq >= due`` to trigger redelivery), breaking the "delay
        defers + redelivers exactly once" contract of
        ``repro.sketch.faults``. Draining here keeps the contract: a
        flushed session has ingested every observation exactly once.
        """
        self._drain(keep_partial=False)
        self._drain_deferred()

    def _drain_deferred(self) -> None:
        """Deliver every pending delayed slice (end-of-stream redelivery)."""
        for due in sorted(self._deferred):
            for di, dw in self._deferred.pop(due):
                self.state = self._compiled(self.state, di, dw)

    def _drain(self, keep_partial: bool) -> None:
        if not self._buf_n:
            return
        items = np.concatenate(self._buf_i) if len(self._buf_i) > 1 \
            else self._buf_i[0]
        weights = np.concatenate(self._buf_w) if len(self._buf_w) > 1 \
            else self._buf_w[0]
        n_full = (len(items) // self.block) * self.block
        for s in range(0, n_full, self.block):
            self.ingest_block(items[s:s + self.block],
                              weights[s:s + self.block])
        tail = len(items) - n_full
        if not keep_partial and tail:
            pad = self.block - tail
            self.ingest_block(np.pad(items[n_full:], (0, pad)),
                              np.pad(weights[n_full:], (0, pad)))
        keep_tail = keep_partial and tail
        rest_i = items[n_full:] if keep_tail else items[:0]
        rest_w = weights[n_full:] if keep_tail else weights[:0]
        self._buf_i = [rest_i] if len(rest_i) else []
        self._buf_w = [rest_w] if len(rest_w) else []
        self._buf_n = len(rest_i)

    # -- windowed batch scheduling (the stats trackers' machinery) ---------

    def push(self, items, weights, tenant: Optional[int] = None) -> None:
        """Ingest one aggregated batch NOW and schedule its expiry.

        After ``window`` further pushes the batch re-ingests with
        negated weights — at most 1/window of the live mass deleted per
        step, the exact alpha <= 2 regime Thm 4 sizes capacity for.
        Immediate ingest keeps the block sequence — and therefore the
        sketch state — bit-identical to the pre-session stats trackers;
        anything still buffered from ``extend``/``observe`` flushes
        FIRST so a mixed-use session never reorders a push's deletions
        ahead of buffered insertions.  (Counters track pushed batches
        only: ``extend`` is raw streaming, outside the window
        accounting.)

        ``tenant`` selects which per-tenant expiry FIFO the batch ages
        on (the window counts pushes PER TENANT, so a hot tenant cannot
        flush a cold tenant's history); ``None`` is the classic
        single-stream schedule.
        """
        self.flush()
        items = np.asarray(items).ravel()
        weights = np.asarray(weights).ravel()
        self.ingest(items, weights)  # validates raw, casts internally
        for di, dw in self.schedule_batch(
                items.astype(np.int32), weights.astype(np.int32), tenant):
            self.ingest(di, dw)

    def schedule_batch(self, items: np.ndarray, weights: np.ndarray,
                       tenant: Optional[int] = None,
                       ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Account one already-ingested batch on the window schedule and
        return the expiry updates now due (negated-weight fragments),
        WITHOUT ingesting them — the sketch service coalesces the due
        expiries of many tenants into its fused blocks instead of paying
        one padded ingest per expiry the way ``push`` does.

        ``push`` is exactly ``ingest`` + ``schedule_batch`` + ingesting
        the due fragments; counters move here so both paths agree.
        """
        self.insertions += int(weights.sum())
        if self.window is None:
            return []
        fifo = self._batch_fifos.setdefault(tenant, collections.deque())
        fifo.append((items, weights))
        due: List[Tuple[np.ndarray, np.ndarray]] = []
        while len(fifo) > self.window:
            di, dw = fifo.popleft()
            self.deletions += int(dw.sum())
            due.append((di, -dw))
        return due

    @property
    def batch_fifo(self) -> Deque[Tuple[np.ndarray, np.ndarray]]:
        """Live (items, weights) batches awaiting expiry on the default
        (tenant=None) schedule (checkpointed by the stats trackers, which
        mutate this deque in place — its identity is stable across
        ``load``)."""
        return self._batch_fifos[None]

    @property
    def batch_fifos(self) -> Dict[Optional[int],
                                  Deque[Tuple[np.ndarray, np.ndarray]]]:
        """All per-tenant expiry FIFOs, keyed by tenant (None = default)."""
        return self._batch_fifos

    @property
    def alpha_bound(self) -> float:
        """Empirical alpha = I / (I - D) (paper Table 2)."""
        live = max(self.insertions - self.deletions, 1)
        return self.insertions / live

    # -- queries (flush first: a query sees every prior update) ------------

    def query_many(self, items) -> jax.Array:
        self.flush()
        return api.query_many(self.spec, self.state, items)

    def query(self, item) -> jax.Array:
        self.flush()
        return api.query(self.spec, self.state, item)

    def topk(self, m: int) -> Tuple[jax.Array, jax.Array]:
        self.flush()
        return api.topk(self.spec, self.state, m)

    def rank_many(self, xs) -> jax.Array:
        self.flush()
        return api.rank_many(self.spec, self.state, xs)

    def rank(self, x) -> int:
        self.flush()
        return api.rank(self.spec, self.state, x)

    def quantile_many(self, qs) -> jax.Array:
        self.flush()
        return api.quantile_many(self.spec, self.state, qs)

    def quantile(self, q: float) -> int:
        self.flush()
        return api.quantile(self.spec, self.state, q)

    # -- merge / consolidation / checkpointing -----------------------------

    def merge_from(self, other: "StreamSession") -> None:
        """Cross-host reduction (mergeable summaries); counters add.

        Specs must agree on everything but ``backend`` (an execution
        path, not a layout): merging different k/variant/bits/shards
        would either break the guarantees silently (variant) or die in
        a shape error deep inside ``state.merge`` (k).  Window schedules
        must match too — merging a window=W session into a window=W'
        one would mix expiry semantics: the merged state holds the other
        session's live mass, but its pending expiries would fire on the
        wrong horizon (or never), silently breaking the bounded-deletion
        alpha the capacity was sized for.  Compatible windowed sessions
        carry the other's pending expiry FIFOs over, so every scheduled
        deletion still fires exactly once.
        """
        import dataclasses

        if dataclasses.replace(self.spec, backend="bank") != \
                dataclasses.replace(other.spec, backend="bank"):
            raise ValueError(
                f"cannot merge sessions of different layouts: "
                f"{self.spec} vs {other.spec} (only `backend` may differ)")
        if self.window != other.window:
            raise ValueError(
                f"cannot merge sessions with mismatched window schedules "
                f"(window={self.window} vs window={other.window}): the "
                f"absorbed session's pending expiries would fire on the "
                f"wrong horizon, silently mixing deletion semantics. "
                f"Re-create both sessions with the same window, or flush "
                f"the windows (push window more batches / observe window "
                f"more items) before merging.")
        self.flush()
        other.flush()
        self.state = api.merge(self.spec, self.state, other.state)
        self.insertions += other.insertions
        self.deletions += other.deletions
        self.error_slack += other.error_slack
        # carry pending expiries: the merged state contains the other
        # session's live mass, so its scheduled deletions must still fire
        # (per tenant — an absorbed tenant's batches keep aging on that
        # tenant's own horizon)
        for t, fifo in other._batch_fifos.items():
            self._batch_fifos.setdefault(
                t, collections.deque()).extend(fifo)
        self._item_fifo.extend(other._item_fifo)

    def consolidated(self):
        """Single-host summary (identity when unsharded)."""
        self.flush()
        return api.consolidate(self.spec, self.state)

    def save(self, include_schedule: bool = False) -> dict:
        """Tagged checkpoint dict of the sketch state.

        ``include_schedule=False`` (the legacy contract): flush pending
        updates into the state, save the sketch only — scheduling state
        (fifos, counters) is the caller's to persist; the stats trackers
        do.

        ``include_schedule=True``: do NOT flush — serialize the live
        scheduling state alongside the sketch (``sched_*`` keys: the
        unflushed buffer, both expiry FIFOs, the insertion/deletion
        totals, the block-sequence cursor, the window and the resize
        ``error_slack``) so a ``load`` of this dict resumes the session
        mid-stream with no observation lost, double-counted, or expired
        on the wrong horizon.  This is also the checkpoint
        ``elastic.recover_session`` rebuilds from (``sched_seq`` keys
        its replay).
        """
        if not include_schedule:
            self.flush()
            return api.save(self.spec, self.state)
        d = api.save(self.spec, self.state)
        cat = lambda frags: (np.concatenate(frags) if len(frags) > 1
                             else frags[0] if frags
                             else np.zeros(0, np.int32))
        d["sched_buf_items"] = cat(self._buf_i)
        d["sched_buf_weights"] = cat(self._buf_w)
        d["sched_item_fifo_items"] = np.asarray(
            [i for i, _ in self._item_fifo], np.int32)
        d["sched_item_fifo_weights"] = np.asarray(
            [w for _, w in self._item_fifo], np.int32)
        # batch FIFOs flatten across tenants in a deterministic key
        # order (None first, then ascending tenant); sched_batch_tenants
        # tags each batch's owner FIFO (-1 = the default None schedule)
        # — the failing-before regression: pre-tenant checkpoints
        # collapsed every tenant's pending expiries onto one FIFO
        keys = sorted(self._batch_fifos,
                      key=lambda t: (t is not None, t if t is not None else 0))
        flat_b = [(t, b, w) for t in keys for b, w in self._batch_fifos[t]]
        d["sched_batch_items"] = cat([b for _, b, _ in flat_b])
        d["sched_batch_weights"] = cat([w for _, _, w in flat_b])
        d["sched_batch_lens"] = np.asarray(
            [len(b) for _, b, _ in flat_b], np.int64)
        d["sched_batch_tenants"] = np.asarray(
            [-1 if t is None else int(t) for t, _, _ in flat_b], np.int64)
        d["sched_insertions"] = self.insertions
        d["sched_deletions"] = self.deletions
        d["sched_seq"] = self._seq
        d["sched_window"] = -1 if self.window is None else int(self.window)
        d["sched_error_slack"] = self.error_slack
        # pending delayed fault slices: a crash between a delay fault and
        # its due block must not lose the slice across save/load
        flat = [(due, di, dw) for due in sorted(self._deferred)
                for di, dw in self._deferred[due]]
        d["sched_deferred_due"] = np.asarray(
            [due for due, _, _ in flat], np.int64)
        d["sched_deferred_lens"] = np.asarray(
            [len(di) for _, di, _ in flat], np.int64)
        d["sched_deferred_items"] = cat([np.asarray(di, np.int32)
                                         for _, di, _ in flat])
        d["sched_deferred_weights"] = cat([np.asarray(dw, np.int32)
                                           for _, _, dw in flat])
        return d

    def load(self, d: dict) -> None:
        """Restore from a ``save`` dict or a pre-redesign stats layout,
        adapting the spec's kind/shards to what the dict holds.

        ALL scheduling state resets together — buffers, expiry FIFOs and
        the insertion/deletion totals — so the session is never half-old
        (counters describing batches whose expiries were dropped).
        A ``save(include_schedule=True)`` dict then restores the full
        scheduling state on top (crash/resume resumes mid-stream);
        callers that persist scheduling state out-of-band (the stats
        trackers) restore their counters and FIFO after this call.
        """
        self._buf_i, self._buf_w, self._buf_n = [], [], 0
        # keep the None deque's OBJECT identity: the stats trackers hold
        # a live alias through the batch_fifo property
        none_fifo = self._batch_fifos[None]
        none_fifo.clear()
        self._batch_fifos = {None: none_fifo}
        self._item_fifo.clear()
        self.insertions = 0
        self.deletions = 0
        self.error_slack = 0
        self._seq = 0
        self._replay.clear()
        self._deferred = {}
        self.spec = api.infer_spec(self.spec, d)
        self.state = api.restore(self.spec, d)
        self._compiled = _ingest_fn(self.spec, self.block, self.donate)
        if "sched_seq" in d:
            self._restore_schedule(d)

    def _restore_schedule(self, d: dict) -> None:
        saved_w = int(np.asarray(d["sched_window"]))
        saved_window = None if saved_w < 0 else saved_w
        if self.window != saved_window:
            raise ValueError(
                f"checkpoint carries window={saved_window} but this "
                f"session was built with window={self.window}; resuming "
                f"would re-schedule its pending expiries on the wrong "
                f"horizon. Construct the session with "
                f"window={saved_window} to resume this checkpoint.")
        bi = np.asarray(d["sched_buf_items"], np.int32)
        bw = np.asarray(d["sched_buf_weights"], np.int32)
        self._buf_i = [bi] if len(bi) else []
        self._buf_w = [bw] if len(bw) else []
        self._buf_n = len(bi)
        self._item_fifo = collections.deque(
            (int(i), int(w)) for i, w in zip(
                np.asarray(d["sched_item_fifo_items"]),
                np.asarray(d["sched_item_fifo_weights"])))
        cat_i = np.asarray(d["sched_batch_items"], np.int32)
        cat_w = np.asarray(d["sched_batch_weights"], np.int32)
        lens = np.asarray(d["sched_batch_lens"], np.int64)
        # pre-tenant checkpoints carry no tenant tags: everything loads
        # onto the default (None) schedule, the pre-tenant behavior
        tags = np.asarray(d.get("sched_batch_tenants",
                                np.full(len(lens), -1)), np.int64)
        s = 0
        for n, t in zip(lens, tags):
            n = int(n)
            key = None if int(t) < 0 else int(t)
            self._batch_fifos.setdefault(
                key, collections.deque()).append(
                    (cat_i[s:s + n], cat_w[s:s + n]))
            s += n
        self.insertions = int(np.asarray(d["sched_insertions"]))
        self.deletions = int(np.asarray(d["sched_deletions"]))
        self._seq = int(np.asarray(d["sched_seq"]))
        self.error_slack = int(np.asarray(d["sched_error_slack"]))
        # older schedule checkpoints predate deferred-slice carry-over
        if "sched_deferred_due" in d:
            dd_i = np.asarray(d["sched_deferred_items"], np.int32)
            dd_w = np.asarray(d["sched_deferred_weights"], np.int32)
            self._deferred = {}
            s = 0
            for due, n in zip(np.asarray(d["sched_deferred_due"], np.int64),
                              np.asarray(d["sched_deferred_lens"], np.int64)):
                due, n = int(due), int(n)
                self._deferred.setdefault(due, []).append(
                    (dd_i[s:s + n], dd_w[s:s + n]))
                s += n


class BlockFeeder:
    """Host-side two-slot feeder that keeps the compiled ingest saturated.

    The device half of the double-buffered ingest pipeline (DESIGN.md
    §14) streams tiles inside the fused kernel; this is the host half.
    ``feed(items, weights)`` *stages* block i (async ``jax.device_put``
    of the padded arrays) and *dispatches* block i-1 — so the host→device
    transfer and numpy conversion of the next block overlap the device
    compute of the current one, the same two-slot copy idiom as the
    kernel's VMEM pipeline:

        slot A: block i-1  dispatched, computing on device
        slot B: block i    staging host->device

    At most ``depth`` ingests stay in flight (backpressure via
    ``block_until_ready`` on the oldest) so a fast host cannot queue
    unbounded device work. ``flush()`` dispatches the last staged block
    and synchronizes.

    Blocks must be exactly session-block-sized and zero-weight padded
    (the ``StreamSession.ingest_block`` contract). Feeding through a
    feeder is bit-identical to calling ``ingest_block`` sequentially —
    only the overlap changes (pinned in tests/test_platform.py).
    """

    def __init__(self, session: StreamSession, depth: int = 2):
        self.session = session
        self.depth = max(1, int(depth))
        self._staged: Optional[Tuple[jax.Array, jax.Array]] = None
        self._inflight: Deque = collections.deque()

    def feed(self, items, weights) -> None:
        staged = (
            jax.device_put(np.asarray(items, dtype=np.int32)),
            jax.device_put(np.asarray(weights, dtype=np.int32)),
        )
        if self._staged is not None:
            self._dispatch(*self._staged)
        self._staged = staged

    def _dispatch(self, items, weights) -> None:
        self.session.ingest_block(items, weights)
        self._inflight.append(self.session.state)
        while len(self._inflight) > self.depth:
            jax.block_until_ready(self._inflight.popleft())

    def flush(self):
        """Dispatch the staged block, wait for the device, return state."""
        if self._staged is not None:
            self._dispatch(*self._staged)
            self._staged = None
        while self._inflight:
            jax.block_until_ready(self._inflight.popleft())
        return self.session.state


__all__ = ["BlockFeeder", "StreamSession", "_ingest_fn",
           "ingest_cache_spec", "ingest_cache_stats"]
