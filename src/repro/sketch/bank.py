"""Unified bank engine: one fused multi-row SpaceSaving± ingest core.

The paper's SpaceSaving± update (Algs 1-4) and its Dyadic extension
(Algs 5-6) are the same counter-summary algorithm instantiated at
different row granularities — the "SpaceSaving± Family" follow-up
(PAPERS.md) treats the variants as one family over a shared summary.
This module is that observation as code: ONE stacked ``(R, k)``
:class:`SketchState` bank, per-row capacity masks (BLOCKED sentinel
padding), and a pluggable **router** deciding what a row means —

  * :class:`HashShardRouter`   rows are hash shards; every item id is
    owned by exactly one row (``repro.sketch.sharded``);
  * :class:`DyadicLevelRouter` rows are dyadic layers; every item feeds
    every row as ``x >> level`` (``repro.sketch.dyadic``);
  * :class:`ShardLevelRouter`  the composition: rows are
    (shard, level) pairs, item x feeds row (shard_of(x >> l), l) — the
    mesh-distributed Dyadic bank (``repro.sketch.dyadic_sharded``);
  * :class:`TenantRouter`      rows are tenants (× per-tenant hash
    shards); composite keys (tenant << item_bits) | item route to the
    owning tenant's rows only — the multi-tenant service bank
    (``repro.sketch.tenant``).

Routers are frozen dataclasses (hashable → jit-static) with two duties:
``route_dense(items, weights) -> (R, B) row-sorted views`` and, for
partition routers, ``owner_of(items) -> owner row per id``. Both router
kinds share ONE ``B log B`` sort of the raw block: hash routing
broadcasts the sorted block with foreign weights masked to 0, level
routing right-shifts it (monotone, so every row view stays ascending).

Two fused ingest cores sit under ``update_block_fused``:

  * ``_fused_partition`` — the hash-sharded fast path (PR 3): phase 1
    runs ONCE on global (B,) arrays (shared sort, in-place segment
    aggregation, one searchsorted monitored match for all rows, ONE
    packed-key grouping sort building every row's
    [units | non-units | consumed] layout), and only the O(k)-per-row
    phases run batched over the bank.
  * ``_fused_dense`` — the broadcast path: batched phase 1 directly on
    the (R, B) matrices (per-row prefix-sum aggregation, vmapped
    first-occurrence match, ONE batched within-row grouping sort) with
    no per-row vmap of scatter ops.

Both feed the same banked phase 2, ``residual_phase_banked``: all rows'
eviction loops in lockstep on the FLAT (R, k) store with one-hot
where-mask updates — semantically ``vmap(phases.residual_phase)`` but
without the batched scatter/gather ops vmap generates (CPU XLA lowers
those to per-element loops costing ~4x a plain trip). Results are
bit-identical to running ``blocks.block_update`` per row on that row's
own substream/view — the invariant every client's differential test
pins (tests/test_sharded.py, test_dyadic_jax.py, test_bank.py).

Row layout contract (DESIGN.md §10): row r's live capacity is
``cap_r <= k``; slots beyond it carry BLOCKED ids, INT_MAX counts and
zero errors — inert under every phase. Weight > 0 insert, < 0 delete,
0 padding; item ids non-negative (negative = sentinel).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from . import state as st
from .phases import (
    _stable_partition_perm,
    fill_empty_slots,
    segment_nets,
    waterfill_unit_inserts,
)
from .state import (BLOCKED, EMPTY, VARIANT_LAZY, SketchState, _INT_MAX,
                    sat_add)


def init(capacities: Union[int, Sequence[int]],
         num_rows: Optional[int] = None) -> SketchState:
    """Empty (R, k) bank with per-row live capacities.

    ``capacities``: either a per-row capacity list (rows with smaller
    caps pad their tail with BLOCKED sentinel slots — ids = -2,
    counts = INT_MAX, errors = 0, inert under every phase) or a single
    int applied to ``num_rows`` equal rows.
    """
    if isinstance(capacities, (int, np.integer)):
        assert num_rows is not None and num_rows >= 1
        caps = [int(capacities)] * num_rows
    else:
        caps = [int(c) for c in capacities]
        assert num_rows is None or num_rows == len(caps)
    k = max(caps)
    lane = np.arange(k)[None, :]
    real = lane < np.asarray(caps)[:, None]  # (R, k) live-slot mask
    return SketchState(
        ids=jnp.asarray(np.where(real, int(EMPTY), int(BLOCKED)), jnp.int32),
        counts=jnp.asarray(np.where(real, 0, int(_INT_MAX)), jnp.int32),
        errors=jnp.zeros((len(caps), k), jnp.int32),
    )


def row_capacities(bank: SketchState) -> list:
    """Live (non-BLOCKED) counters per row — the inverse of ``init``."""
    ids = jax.device_get(bank.ids)
    return [int(c) for c in np.asarray(ids != int(BLOCKED)).sum(1)]


def shard_of(items: jax.Array, num_shards: int) -> jax.Array:
    """Owner shard of each item id: lowbias32 avalanche hash mod S.

    A multiplicative-xorshift finalizer (not ``id % S``) so that
    structured id spaces — strided token ids, dyadic prefixes, expert
    indices — still spread uniformly. Pure function of (id, S): any
    host, device or restart routes a uid identically (the routing
    invariant tests/test_sharded.py pins).
    """
    x = items.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return (x % jnp.uint32(num_shards)).astype(jnp.int32)


def sort_block(items: jax.Array, universe_bits: Optional[int]) -> jax.Array:
    """Shared ascending-id sort permutation for the whole bank.

    Packed-key single sort when the static universe bound proves
    ``item * B`` fits int32 (argsort lowers ~4x slower on CPU XLA), else
    one argsort — either way the ONLY B log B sort paid per block.
    """
    B = items.shape[0]
    if universe_bits is not None and universe_bits + (B - 1).bit_length() <= 31:
        return _stable_partition_perm(items)
    return jnp.argsort(items)


# ---------------------------------------------------------------------------
# Routers: what a bank row means
# ---------------------------------------------------------------------------

def _partition_route_dense(router, items: jax.Array,
                           weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Shared partition routing: (B,) block -> (R, B) row views.

    ONE shared sort, the sorted block broadcast to every row with
    foreign weights masked to 0. Every row stays ascending, so
    downstream aggregation runs sorted-free, and each row aggregates to
    exactly its own (uid, net) multiset: zero-net foreign uniques are
    dropped by the validity mask, preserving bit-identity with
    independently built rows.
    """
    items = items.astype(jnp.int32)
    weights = weights.astype(jnp.int32)
    order = sort_block(items, router.universe_bits)
    s_items = items[order]
    s_w = weights[order]
    owner = router.owner_of(s_items)
    rows = jnp.arange(router.num_rows, dtype=jnp.int32)[:, None]
    w_routed = jnp.where(owner[None, :] == rows, s_w[None, :], 0)
    items_b = jnp.broadcast_to(
        s_items[None, :], (router.num_rows, items.shape[0]))
    return items_b, w_routed


@dataclasses.dataclass(frozen=True)
class HashShardRouter:
    """Partition router: row = lowbias32 hash shard; one owner row per id.

    ``universe_bits``: static log2(universe) bound enabling the packed
    single-sort router (see ``sort_block``).
    """

    num_shards: int
    universe_bits: Optional[int] = None
    kind = "partition"

    @property
    def num_rows(self) -> int:
        return self.num_shards

    def owner_of(self, items: jax.Array) -> jax.Array:
        return shard_of(items, self.num_shards)

    def route_dense(self, items: jax.Array,
                    weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(B,) block -> (S, B): sorted block broadcast, foreign weights 0."""
        return _partition_route_dense(self, items, weights)


@dataclasses.dataclass(frozen=True)
class TenantRouter:
    """Partition router for multi-tenant banks: row = tenant (× shard).

    Items arrive as composite routing keys ``(tenant << item_bits) |
    item`` (``repro.sketch.tenant.pack_keys``). The router peels the
    tenant off the high bits, and — when ``num_shards > 1`` — hashes the
    *item part* with the same lowbias32 ``shard_of`` a per-tenant
    ``HashShardRouter(num_shards)`` applies to raw items, so each
    tenant's rows partition its stream exactly like an independently
    built sharded sketch (the bit-identity tests/test_tenant.py pins).
    Rows are tenant-major: tenant t owns rows ``[t*S, (t+1)*S)``.

    Composite keys from different tenants never collide, so ownership —
    and therefore monitoring, queries and top-k — never crosses a tenant
    boundary: isolation is routing, not bookkeeping. Composes with the
    dyadic layout the way ``ShardLevelRouter`` composes shard × level: a
    dyadic bank over composite keys answers per-tenant ranks/quantiles
    as range differences inside the tenant's key range
    (``repro.sketch.tenant.tenant_rank_many``).
    """

    num_tenants: int
    item_bits: int
    num_shards: int = 1
    kind = "partition"

    @property
    def tenant_bits(self) -> int:
        return (self.num_tenants - 1).bit_length()

    @property
    def universe_bits(self) -> int:
        # static composite-key bound -> packed single-sort eligibility
        return self.item_bits + self.tenant_bits

    @property
    def num_rows(self) -> int:
        return self.num_tenants * self.num_shards

    @property
    def monotone_owner(self) -> bool:
        """Owner row is non-decreasing in composite-key order.

        With one row per tenant the owner is the key's high bits, so the
        fused ingest's shared sort leaves every row's entries in one
        contiguous run — ``_fused_partition`` swaps its (R, B) one-hot
        ranks/tallies for O(B + R) prefix-sum differences, the step that
        otherwise dominates once rows reach the thousands (multi-tenant
        banks). Per-tenant hash shards break monotonicity.
        """
        return self.num_shards == 1

    def owner_of(self, keys: jax.Array) -> jax.Array:
        keys = keys.astype(jnp.int32)
        tenant = jnp.right_shift(keys, self.item_bits)
        if self.num_shards == 1:
            return tenant
        item = jnp.bitwise_and(keys, (1 << self.item_bits) - 1)
        return tenant * self.num_shards + shard_of(item, self.num_shards)

    def route_dense(self, items: jax.Array,
                    weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(B,) block -> (T*S, B): sorted block broadcast, foreign 0."""
        return _partition_route_dense(self, items, weights)


@dataclasses.dataclass(frozen=True)
class DyadicLevelRouter:
    """Broadcast router: row l monitors ``x >> l`` (the dyadic layers)."""

    bits: int
    kind = "dense"

    @property
    def num_rows(self) -> int:
        return self.bits

    def route_dense(self, items: jax.Array,
                    weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(B,) block -> (bits, B) per-layer node views, ONE shared sort.

        Right-shift is monotonic, so the sorted block stays sorted in
        every layer view — each row's aggregation skips its own
        O(B log B) sort.
        """
        items = items.astype(jnp.int32)
        weights = weights.astype(jnp.int32)
        order = sort_block(items, self.bits)
        shifts = jnp.arange(self.bits, dtype=jnp.int32)[:, None]
        items_l = jnp.right_shift(items[order][None, :], shifts)
        # every row shares ONE weight vector: return it (1, B) so the
        # engine's aggregation prefix-sums it once, not ``bits`` times
        return items_l, weights[order][None, :]


@dataclasses.dataclass(frozen=True)
class ShardLevelRouter:
    """Composed shard × level router: row (s, l) monitors the level-l
    nodes owned by hash shard s — rows ordered shard-major
    (``row = s * bits + l``) so a mesh shards the leading axis by
    slicing whole shards.

    Equals sequential application of the two routings (property-tested):
    dyadic-shift first, then hash-partition each layer's node stream.
    """

    bits: int
    num_shards: int
    kind = "dense"

    @property
    def num_rows(self) -> int:
        return self.bits * self.num_shards

    def route_dense(self, items: jax.Array,
                    weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
        nodes, w_l = DyadicLevelRouter(self.bits).route_dense(items, weights)
        B = nodes.shape[1]
        shape = (self.num_rows, B)
        items_b = jnp.broadcast_to(
            nodes[None], (self.num_shards, self.bits, B))
        return items_b.reshape(shape), self.mask_shards(nodes, w_l).reshape(
            shape)

    def mask_shards(self, nodes: jax.Array, w_l: jax.Array) -> jax.Array:
        """(bits, B) level weights -> (S, bits, B) with foreign weights 0.

        The one home of the shard-masking rule: ``route_dense`` reshapes
        its output to engine rows, the dyadic_sharded shard_map path
        partitions it over the mesh as-is — either way the same mask.
        """
        owner = shard_of(nodes, self.num_shards)          # (bits, B)
        rows = jnp.arange(self.num_shards, dtype=jnp.int32)[:, None, None]
        return jnp.where(owner[None] == rows, w_l[None], 0)


Router = Union[HashShardRouter, TenantRouter, DyadicLevelRouter,
               ShardLevelRouter]


# ---------------------------------------------------------------------------
# Banked phase 2: all rows' eviction loops in lockstep on the flat store
# ---------------------------------------------------------------------------

def residual_phase_banked(ids2, cnt2, err2, h_uids, h_net, uoff, start,
                          n_ins, w_del, variant: int):
    """Bank-wide phase 2: every row's eviction loop in lockstep.

    Semantically ``vmap(phases.residual_phase)`` — the while loops run
    until every row lane finishes, ≈ max_r(U_r) trips — but the body
    avoids the batched scatter/gather ops vmap generates (CPU XLA lowers
    those to per-element loops that cost ~4x a plain trip, cancelling
    the 1/S trip reduction of the sharded client). The store stays FLAT
    (R, k): a flat argmin over a row's k slots traverses the same
    elements as the (rows, LANES) tournament's reductions, so with every
    row reduced at once there is nothing for the two-level view to save.
    The body also drops the empty-slot branch of ``phases._pick_slot``
    outright: a row lane is only active while it still has non-unit
    residual inserts, which (phase 1.5) implies the bulk fill consumed
    every empty slot — pure min-count evictions, the same case analysis
    the single-sketch loop resolves dynamically. Inserts are read
    straight from the one global grouped layout at per-row offsets
    (``uoff``); the touched slot updates through a one-hot where-mask
    and finished lanes freeze via an ``active`` mask (the select
    semantics jax gives a batched while_loop). Tie-breaking matches flat
    argmin/argmax (lowest slot index), so results are bit-identical to
    the per-row loop. BLOCKED padding slots (INT_MAX counts, zero
    errors) are never the min count nor a positive-error spread target.
    """
    R, k = ids2.shape
    G = h_uids.shape[0]
    lane = jnp.arange(k, dtype=jnp.int32)[None, :]

    def ins_cond(carry):
        return (carry[0] < n_ins).any()

    def ins_step(carry):
        i, ids2, cnt2, err2 = carry
        active = i < n_ins
        g = jnp.clip(uoff + i, 0, G - 1)
        uid = h_uids[g]
        w = h_net[g]
        sel = jnp.argmin(cnt2, axis=1)
        mc = jnp.take_along_axis(cnt2, sel[:, None], axis=1)[:, 0]
        hot = (lane == sel[:, None]) & active[:, None]
        return (
            i + active.astype(jnp.int32),
            jnp.where(hot, uid[:, None], ids2),
            jnp.where(hot, sat_add(mc, w)[:, None], cnt2),
            jnp.where(hot, mc[:, None], err2),
        )

    _, ids2, cnt2, err2 = jax.lax.while_loop(
        ins_cond, ins_step, (start.astype(jnp.int32), ids2, cnt2, err2))

    if variant != VARIANT_LAZY:
        # the spread's (row, slot) argmax is carried incrementally so the
        # loop condition reads (R,) scalars, not an (R, k) reduction
        def sp_cond(carry):
            rem, _, _, sel, maxe = carry
            return ((rem > 0) & (maxe > 0)).any()

        def sp_step(carry):
            rem, cnt2, err2, sel, maxe = carry
            active = (rem > 0) & (maxe > 0)
            d = jnp.where(active, jnp.minimum(rem, maxe), 0)
            hot = (lane == sel[:, None]) & active[:, None]
            # saturating decrements: d <= maxe = err2[sel] and d <= rem,
            # so all three are exact for in-range states; a count already
            # at the negative rail absorbs the spread instead of wrapping
            nd2 = jnp.negative(d)[:, None]
            cnt2 = jnp.where(hot, sat_add(cnt2, nd2), cnt2)
            err2 = jnp.where(hot, sat_add(err2, nd2), err2)
            sel = jnp.argmax(err2, axis=1)
            maxe = jnp.take_along_axis(err2, sel[:, None], axis=1)[:, 0]
            return sat_add(rem, jnp.negative(d)), cnt2, err2, sel, maxe

        sel0 = jnp.argmax(err2, axis=1)
        maxe0 = jnp.take_along_axis(err2, sel0[:, None], axis=1)[:, 0]
        _, cnt2, err2, _, _ = jax.lax.while_loop(
            sp_cond, sp_step,
            (w_del.astype(jnp.int32), cnt2, err2, sel0, maxe0))
    return ids2, cnt2, err2


# ---------------------------------------------------------------------------
# Dense fused core: batched phase 1 on (R, B) row views
# ---------------------------------------------------------------------------

def phase1_dense_prep(bank: SketchState, row_items: jax.Array,
                      row_weights: jax.Array, variant: int):
    """The XLA half of the dense phase 1: everything that needs sorts,
    searchsorted or scatters, none of which lower inside a Mosaic
    kernel. Returns the per-cell state *delta* instead of mutating the
    bank, so the fused Pallas kernel can apply phases 1-2 on VMEM-
    resident tiles (kernels/sketch_update) while this path's own
    ``phase1_dense`` applies the identical arithmetic in XLA:

      1. per-row prefix-sum aggregation to (head, net) — every row is
         already ascending (router contract), so no sort at all;
      2. monitored matching for ALL rows with one vmapped searchsorted
         of the (R, k) bank ids into their own row's sorted view
         (first occurrence = segment head, where net is valid) ->
         ``delta``, the (R, k) monitored scatter addend;
      3. residual classification + ONE batched within-row grouping sort
         building every row's [units | non-units | consumed-by-fill]
         layout at once (the layout blocks._phase1 builds with two
         partition sorts, collapsed to one since the consumed prefix is
         known up front from in-row insert ranks).

    Only ``bank.ids`` is read (matching and the empty census); counts
    and errors are untouched, so the delta is valid however the
    consumer stages the apply. Returns ``(delta, h_uids, h_net, i0,
    mu, nnu, w_del)`` with ``h_uids``/``h_net`` the flattened (R*B,)
    grouped residual layout.
    """
    R, k = bank.ids.shape
    B = row_items.shape[1]
    row_items = row_items.astype(jnp.int32)
    row_weights = row_weights.astype(jnp.int32)
    idx = jnp.arange(B, dtype=jnp.int32)

    # -- 1. per-row aggregation (rows pre-sorted by the router) -----------
    head, net = segment_nets(row_items, row_weights)
    valid = head & (row_items >= 0) & (net != 0)

    # -- 2. monitored matching, all rows at once --------------------------
    # searchsorted returns the FIRST occurrence = the segment head; the
    # (ids >= 0) guard keeps EMPTY/BLOCKED slots from matching sentinel
    # padding items.
    pos = jnp.clip(jax.vmap(jnp.searchsorted)(row_items, bank.ids), 0, B - 1)
    match = (jnp.take_along_axis(row_items, pos, axis=1) == bank.ids) \
        & (bank.ids >= 0)
    delta = jnp.where(match, jnp.take_along_axis(net, pos, axis=1), 0)
    rows = jnp.broadcast_to(jnp.arange(R, dtype=jnp.int32)[:, None], (R, k))
    monitored = (
        jnp.zeros((R, B), bool)
        .at[rows, jnp.where(match, pos, B)]
        .set(True, mode="drop")
    )

    # -- 3. residual classification + ONE batched grouping sort -----------
    res_ins = valid & ~monitored & (net > 0)
    rank = jnp.cumsum(res_ins, axis=1) - 1      # in-row insert rank
    n_ins = res_ins.sum(axis=1)
    empties = (bank.ids == EMPTY).sum(axis=1)
    i0 = jnp.minimum(n_ins, empties)            # consumed by the bulk fill
    consumed = res_ins & (rank < i0[:, None])
    unit = res_ins & ~consumed & (net == 1)
    nonunit = res_ins & ~consumed & (net != 1)
    if variant == VARIANT_LAZY:
        w_del = jnp.zeros((R,), jnp.int32)
    else:
        res_del = valid & ~monitored & (net < 0)
        w_del = jnp.where(res_del, -net, 0).sum(axis=1)
    klass = jnp.where(
        res_ins, jnp.where(unit, 0, jnp.where(nonunit, 1, 2)), 3)
    # packed-key stable partition per row, ONE batched sort lowering
    perm = jnp.sort(klass * B + idx[None, :], axis=1) % B
    h_uids = jnp.take_along_axis(row_items, perm, axis=1).reshape(-1)
    h_net = jnp.take_along_axis(net, perm, axis=1).reshape(-1)
    mu = unit.sum(axis=1)
    nnu = nonunit.sum(axis=1)
    return delta, h_uids, h_net, i0, mu, nnu, w_del


def phase1_dense(bank: SketchState, row_items: jax.Array,
                 row_weights: jax.Array, variant: int):
    """Batched phases 1-1.75 on row-sorted (R, B) views — no per-row vmap
    of block orchestration, no compaction sorts.

    ``phase1_dense_prep`` (sorts/matching/grouping) followed by the
    in-place apply: saturating phase-1 scatter, then per-row slices of
    the one flattened grouped layout feed batched fill_empty_slots /
    waterfill_unit_inserts. The apply bodies are shared verbatim with
    the fused Pallas tile kernel, so the two stay bit-identical.

    Returns ``(ids1, cnt1, err1, h_uids, h_net, uoff, mu, nnu, w_del)``:
    the bank after the vectorized phases, the flattened (R*B,) grouped
    residual layout, per-row offsets of the unit run (``uoff``), unit /
    non-unit insert counts and summed unmonitored deletion weight — the
    banked residual loop's inputs.
    """
    R, k = bank.ids.shape
    B = row_items.shape[1]
    delta, h_uids, h_net, i0, mu, nnu, w_del = phase1_dense_prep(
        bank, row_items, row_weights, variant)
    counts1 = sat_add(bank.counts, delta)
    uoff = jnp.arange(R, dtype=jnp.int32) * B   # row r's run starts at r*B

    # -- 4. batched O(k) phases on the one global grouped layout ----------
    ids1, cnt1, err1, _ = jax.vmap(
        fill_empty_slots, in_axes=(0, 0, 0, None, None, 0, 0))(
        bank.ids, counts1, bank.errors, h_uids, h_net, i0, uoff + mu + nnu)
    ids1, cnt1, err1 = jax.vmap(
        waterfill_unit_inserts, in_axes=(0, 0, 0, None, 0, 0))(
        ids1, cnt1, err1, h_uids, mu, uoff)
    return ids1, cnt1, err1, h_uids, h_net, uoff, mu, nnu, w_del


def _fused_dense(bank: SketchState, row_items: jax.Array,
                 row_weights: jax.Array, variant: int) -> SketchState:
    """Dense fused ingest: batched phase 1 + the banked residual loop."""
    ids1, cnt1, err1, h_uids, h_net, uoff, mu, nnu, w_del = phase1_dense(
        bank, row_items, row_weights, variant)
    ids1, cnt1, err1 = residual_phase_banked(
        ids1, cnt1, err1, h_uids, h_net, uoff, mu, mu + nnu, w_del, variant)
    return SketchState(ids1, cnt1, err1)


@functools.partial(jax.jit, static_argnames=("variant",))
def update_rows(bank: SketchState, row_items: jax.Array,
                row_weights: jax.Array, variant: int = 2) -> SketchState:
    """Public dense entry: ingest pre-routed row-sorted (R, B) views.

    For callers that route themselves (the shard_map local program, the
    dyadic bank after its shared sort). Every row of ``row_items`` must
    be ascending; bit-identical to ``blocks.block_update(row, ...,
    assume_sorted=True)`` per row.
    """
    return _fused_dense(bank, row_items, row_weights, variant)


# ---------------------------------------------------------------------------
# Partition fused core: global phase 1, one grouping sort for all rows
# ---------------------------------------------------------------------------

def _fused_partition(bank: SketchState, items: jax.Array, weights: jax.Array,
                     router: HashShardRouter, variant: int) -> SketchState:
    """Fused single-launch partition ingest: global phase 1, banked phase 2.

    The single-sketch two-phase pipeline (blocks._phase1) run once on
    global arrays with row-aware grouping, so the B-wide sorts and the
    monitored matching are paid once — not once per row:

      1. one shared sort; one global aggregation to (uids, net);
      2. monitored matching for ALL rows with one searchsorted of the
         stacked (S, k) ids into the global uniques (same total work as
         the single sketch: an id matches only in its owner row);
      3. ONE packed-key partition groups residual inserts into every
         row's [units | non-units | consumed-by-fill] layout at once
         (the consumed prefix is known up front from in-row ranks);
      4. per-row slices of that one global array feed batched
         fill_empty_slots / waterfill_unit_inserts and the flat banked
         residual loop, whose trip count is max_s(non-unit_s) ≈ U/S
         instead of U.

    Per-row results are bit-identical to blocks.block_update on the
    row's own substream (each step sees exactly the row's aggregated
    multiset in the same order) — pinned against
    ``sharded.update_block_serial_reference`` by tests and
    BENCH_sharded.json.
    """
    S = router.num_rows
    k = bank.ids.shape[1]
    items = items.astype(jnp.int32)
    weights = weights.astype(jnp.int32)
    B = items.shape[0]
    if (3 * S + 1) * B >= 2**31:
        # the row-grouping packed key is klass * B + idx with 3S + 1
        # classes — the one partition call whose key range grows with S
        raise ValueError(
            f"fused partition update needs (3*rows+1)*block < 2^31 for the "
            f"packed grouping sort; got rows={S}, block={B}. Use "
            f"path='vmap' (or fewer rows per launch).")

    # -- 1. shared sort + in-place segment aggregation ---------------------
    # Same prefix-sum aggregation as blocks._aggregate_block but WITHOUT
    # its head-compaction sort: the fused path matches and groups
    # directly against the raw sorted block (a segment's head position
    # stands in for the compacted unique), so the one grouping sort in
    # step 3 does all the compaction this path ever needs.
    order = sort_block(items, router.universe_bits)
    uids = items[order]      # sorted; segment heads carry the uniques
    wts = weights[order]
    idx = jnp.arange(B, dtype=jnp.int32)
    head, net = segment_nets(uids[None, :], wts[None, :])
    head, net = head[0], net[0]  # per-unique net, valid at head positions
    valid = head & (uids >= 0) & (net != 0)
    owner = router.owner_of(uids)  # read at head positions only

    # -- 2. monitored matching, all rows at once ---------------------------
    # searchsorted returns the FIRST occurrence = the segment head; the
    # (flat_ids >= 0) guard keeps EMPTY slots from matching -1 padding
    # items (the compacted path got this from its sentinel remap).
    flat_ids = bank.ids.reshape(-1)
    pos = jnp.clip(jnp.searchsorted(uids, flat_ids), 0, B - 1)
    match = (uids[pos] == flat_ids) & (flat_ids >= 0)
    counts1 = sat_add(bank.counts, jnp.where(match, net[pos], 0).reshape(S, k))
    monitored = (
        jnp.zeros((B,), bool)
        .at[jnp.where(match, pos, B)]
        .set(True, mode="drop")
    )

    # -- 3. residual classification + ONE row-major grouping sort ----------
    # blocks._phase1 builds the [units | non-units | consumed] layout per
    # sketch with a second partition AFTER the empty fill; here the
    # consumed prefix ("the leading i0_s inserts the bulk empty fill
    # places") is known up front from each entry's rank within its row
    # — an (S, B) one-hot cumsum — so one packed sort builds all S
    # layouts back to back. Per-row tallies come from the same (S, B)
    # masks (no segment_sum: CPU XLA serializes B-wide scatter-adds).
    owner_c = jnp.clip(owner, 0, S - 1)
    res_ins = valid & ~monitored & (net > 0)
    empties_s = (bank.ids == EMPTY).sum(axis=1)
    if getattr(router, "monotone_owner", False):
        # owner is non-decreasing in sorted-key order (tenant-major
        # composite keys): each row's entries form one contiguous run,
        # so in-row ranks and per-row tallies are prefix-sum
        # differences at the run boundaries — O(B + S) where the dense
        # branch below pays (S, B). At S ~ 1000 rows this is the
        # difference between the fused launch beating per-row sessions
        # and losing to them (BENCH_service.json, fused_vs_sessions).
        rows_s = jnp.arange(S, dtype=jnp.int32)
        start_s = jnp.searchsorted(owner, rows_s, side="left")
        end_s = jnp.searchsorted(owner, rows_s, side="right")

        def seg_sum(vals):
            p = jnp.cumsum(vals.astype(jnp.int32))
            p = jnp.concatenate([jnp.zeros(1, jnp.int32), p])
            return p[end_s] - p[start_s]

        cum_ins = jnp.cumsum(res_ins.astype(jnp.int32))
        ex_ins = cum_ins - res_ins                 # exclusive prefix
        n_ins_s = seg_sum(res_ins)
        rank = ex_ins - ex_ins[start_s[owner_c]]   # valid at res_ins
        i0_s = jnp.minimum(n_ins_s, empties_s)
        consumed = res_ins & (rank < i0_s[owner_c])
        unit = res_ins & ~consumed & (net == 1)
        nonunit = res_ins & ~consumed & (net != 1)
        if variant == VARIANT_LAZY:
            w_del_s = jnp.zeros((S,), jnp.int32)
        else:
            res_del = valid & ~monitored & (net < 0)
            w_del_s = seg_sum(jnp.where(res_del, -net, 0))
        mu_s = seg_sum(unit)
        nnu_s = seg_sum(nonunit)
    else:
        shard_rows = jnp.arange(S, dtype=jnp.int32)[:, None]
        owner_mat = owner[None, :] == shard_rows                  # (S, B)
        ins_mat = owner_mat & res_ins[None, :]
        rank_mat = jnp.cumsum(ins_mat, axis=1)                    # inclusive
        n_ins_s = rank_mat[:, -1]
        rank = jnp.take_along_axis(rank_mat, owner_c[None, :], axis=0)[0] - 1
        i0_s = jnp.minimum(n_ins_s, empties_s)
        consumed = res_ins & (rank < i0_s[owner_c])
        unit = res_ins & ~consumed & (net == 1)
        nonunit = res_ins & ~consumed & (net != 1)
        if variant == VARIANT_LAZY:
            w_del_s = jnp.zeros((S,), jnp.int32)
        else:
            res_del = valid & ~monitored & (net < 0)
            w_del_s = jnp.where(owner_mat & res_del[None, :],
                                -net[None, :], 0).sum(axis=1)
        mu_s = (owner_mat & unit[None, :]).sum(axis=1)
        nnu_s = (owner_mat & nonunit[None, :]).sum(axis=1)
    klass = jnp.where(
        res_ins,
        owner_c * 3 + jnp.where(unit, 0, jnp.where(nonunit, 1, 2)),
        3 * S,
    )
    perm = _stable_partition_perm(klass)
    h_uids = uids[perm]
    h_net = net[perm]
    cc = jnp.stack([mu_s, nnu_s, i0_s], axis=1).reshape(-1)       # (3S,)
    class_off = jnp.cumsum(cc) - cc
    uoff_s = class_off[0::3]   # start of row s's [units | non-units] run
    coff_s = class_off[2::3]   # start of row s's consumed (fill) run

    # -- 4. batched O(k) phases + flat banked residual loop ----------------
    # All three consumers read the ONE global grouped layout at
    # per-row offsets — no per-row (S, B) slices materialize.
    ids1, cnt1, err1, _ = jax.vmap(
        fill_empty_slots, in_axes=(0, 0, 0, None, None, 0, 0))(
        bank.ids, counts1, bank.errors, h_uids, h_net, i0_s, coff_s)
    ids1, cnt1, err1 = jax.vmap(
        waterfill_unit_inserts, in_axes=(0, 0, 0, None, 0, 0))(
        ids1, cnt1, err1, h_uids, mu_s, uoff_s)
    ids1, cnt1, err1 = residual_phase_banked(
        ids1, cnt1, err1, h_uids, h_net, uoff_s, mu_s, mu_s + nnu_s,
        w_del_s, variant)
    return SketchState(ids1, cnt1, err1)


@functools.partial(jax.jit, static_argnames=("router", "variant"))
def update_block_fused(bank: SketchState, items: jax.Array,
                       weights: jax.Array, router: Router,
                       variant: int = 2) -> SketchState:
    """Ingest one (B,) block into the whole bank with a single launch.

    Dispatches on the router kind at trace time (routers are static):
    partition routers take the global-phase-1 fast path, broadcast
    routers the dense batched path. Either way the result is
    bit-identical to updating each row with ``blocks.block_update`` on
    the row's own routed view.
    """
    if router.kind == "partition":
        return _fused_partition(bank, items, weights, router, variant)
    row_items, row_weights = router.route_dense(items, weights)
    return _fused_dense(bank, row_items, row_weights, variant)


@functools.partial(jax.jit, static_argnames=("variant", "universe_bits"))
def update_single(state: SketchState, items: jax.Array, weights: jax.Array,
                  variant: int = 2,
                  universe_bits: Optional[int] = None) -> SketchState:
    """Fused ingest of a flat (k,) sketch as a one-row bank.

    The engine backend for single-sketch clients (the stats facade):
    identical semantics to ``blocks.block_update`` — a one-shard
    partition is the whole block — through the same fused core every
    multi-row client runs, so there is ONE hot path to optimize.
    Bit-identity to ``block_update`` is pinned in tests/test_bank.py.
    """
    bank = jax.tree.map(lambda x: x[None], state)
    out = _fused_partition(bank, items, weights,
                           HashShardRouter(1, universe_bits), variant)
    return jax.tree.map(lambda x: x[0], out)


# ---------------------------------------------------------------------------
# Banked queries / merge / consolidate
# ---------------------------------------------------------------------------

@jax.jit
def query_rows(bank: SketchState, rows: jax.Array,
               items: jax.Array) -> jax.Array:
    """Estimated count of ``items[i]`` read from its owner row ``rows[i]``.

    The owner-row read every client's query path reduces to: an id is
    monitored (if at all) in exactly one row of a partition, so the
    global answer is the owner row's answer — no cross-row combination
    and therefore no merge cross-term error.
    """
    ids_r = bank.ids[rows]                       # (n, k) row gather
    cnt_r = bank.counts[rows]
    # sentinel slots (EMPTY/BLOCKED/POISON) are masked out so querying a
    # negative id returns 0 instead of the padding slots' garbage counts
    eq = (ids_r == items.astype(jnp.int32)[:, None]) & (ids_r >= 0)
    return jnp.where(eq, cnt_r, 0).sum(axis=1) * eq.any(axis=1)


def topk_bank(bank: SketchState, m: int) -> Tuple[jax.Array, jax.Array]:
    """Global top-m (ids, counts): flat top-k over all R·k slots.

    Exact given the per-row states under a partition router (every
    candidate heavy hitter is monitored by its owner row with its full
    estimated count). Sentinel slots (EMPTY/BLOCKED) never surface.
    """
    ids = bank.ids.reshape(-1)
    counts = jnp.where(ids < 0, jnp.int32(-2**31), bank.counts.reshape(-1))
    vals, idx = jax.lax.top_k(counts, m)
    return ids[idx], vals


@functools.partial(jax.jit, static_argnames=("m",))
def topk_rows(bank: SketchState, rows: jax.Array,
              m: int) -> Tuple[jax.Array, jax.Array]:
    """Top-m (ids, counts) over a row subset; ``m <= len(rows) * k``.

    ``topk_bank`` restricted to ``rows`` (a traced index array, so one
    compiled gather serves every tenant). When the subset is
    ownership-closed under a partition router — a tenant's rows — the
    answer is exact for that subset and blind to every other row: the
    never-cross-tenants top-k read.
    """
    ids = bank.ids[rows].reshape(-1)
    counts = jnp.where(ids < 0, jnp.int32(-2**31),
                       bank.counts[rows].reshape(-1))
    vals, idx = jax.lax.top_k(counts, m)
    return ids[idx], vals


@jax.jit
def merge_banks(a: SketchState, b: SketchState) -> SketchState:
    """Row-wise mergeable-summaries merge of two same-shape banks.

    Valid because both banks route with the same router: row r of either
    bank only ever monitored ids routed to r, so the pairing is exact
    and the merged bank keeps the row-ownership invariant.
    """
    return jax.vmap(st.merge)(a, b)


def consolidate(bank: SketchState, merge_fn=st.merge) -> SketchState:
    """Fold the leading row axis into ONE summary (checkpoint compaction).

    A tree of ``merge_fn`` (default ``state.merge``, which is
    BLOCKED-aware) reduces (R, k) -> (k,): the compact global view for
    checkpoints/telemetry, carrying the standard merged-summary error
    bounds (unlike owner-row queries on the live bank, which are
    merge-error-free). Not an inverse of routing — R·k counters collapse
    to k. Callers with extra trailing axes pass a lifted merge
    (dyadic_sharded folds (S, bits, k) -> (bits, k) with
    ``jax.vmap(state.merge)``).
    """
    rows = [jax.tree.map(lambda x: x[r], bank)
            for r in range(bank.ids.shape[0])]
    while len(rows) > 1:
        nxt = [merge_fn(rows[i], rows[i + 1])
               for i in range(0, len(rows) - 1, 2)]
        if len(rows) % 2:
            nxt.append(rows[-1])
        rows = nxt
    return rows[0]


# ---------------------------------------------------------------------------
# Second-bank coupling: the Double SpaceSaving± hooks
# ---------------------------------------------------------------------------

def split_signed(weights: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Split one signed block into the family's two insert-only streams.

    Double SpaceSaving± (family paper, PAPERS.md) feeds insertions into
    one summary and deletions into a second one *as insertions*; the
    estimator subtracts. Zero weights stay zero on both sides, so block
    padding remains padding for both banks.
    """
    w = weights.astype(jnp.int32)
    return jnp.maximum(w, 0), jnp.maximum(-w, 0)


@functools.partial(jax.jit, static_argnames=("router", "variant"))
def update_pair(ins_bank: SketchState, del_bank: SketchState,
                items: jax.Array, weights: jax.Array, router: Router,
                variant: int = 2) -> Tuple[SketchState, SketchState]:
    """Coupled two-bank ingest: ONE launch updating both family banks.

    The engine hook the Double SpaceSaving± backend builds on
    (``repro.sketch.family``): both banks share the router (and hence the
    row-ownership invariant), each sees an insert-only stream, so the
    fused cores run in their monitored-heavy sweet spot and the lazy/SS±
    distinction vanishes (no unmonitored deletions ever reach either
    bank). Banks may have different per-row capacities (the family's
    k_I/k_D split).
    """
    w_ins, w_del = split_signed(weights)
    return (
        update_block_fused(ins_bank, items, w_ins, router, variant),
        update_block_fused(del_bank, items, w_del, router, variant),
    )


__all__ = [
    "init",
    "row_capacities",
    "shard_of",
    "sort_block",
    "HashShardRouter",
    "TenantRouter",
    "DyadicLevelRouter",
    "ShardLevelRouter",
    "Router",
    "residual_phase_banked",
    "phase1_dense",
    "phase1_dense_prep",
    "update_rows",
    "update_block_fused",
    "update_single",
    "query_rows",
    "topk_bank",
    "topk_rows",
    "merge_banks",
    "consolidate",
    "split_signed",
    "update_pair",
]
