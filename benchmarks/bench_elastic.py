"""Elastic + fault-tolerance benchmarks: live resize, shard-loss
recovery, and quality across a fault.

Three tables, all written to ``BENCH_elastic.json`` at the repo root:

  * **resize** — wall time of the consolidate-free S -> S' re-route
    (``elastic.reshard`` / ``reshard_dyadic``) on a warm state, plus the
    counters moved/dropped, the tracked ``error_slack``, and
    phi-heavy-hitter recall/precision before vs after the resize (the
    acceptance framing: estimates stay within the summed bound, so
    recall must not regress beyond slack).
  * **recovery** — a seeded fault plan (corrupt + drop + duplicate)
    hits a live session; the table records recall/precision of the
    faulted state, then the checkpoint+replay rebuild time
    (``elastic.recover_session``), the blocks replayed, whether the
    recovered state is bit-identical to a never-failed twin, and the
    restored recall/precision.

Both tables run the frequency AND quantile (dyadic) kinds.  Wall-times
are 2-core CPU numbers — relative trends only (DESIGN.md §12);
bit-exactness and recall are exact.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

import jax

from benchmarks.common import (
    csv_print,
    dist_stream,
    exact_freqs,
    recall_precision,
    stream_blocks,
    write_bench_json,
)
from repro.sketch import api, elastic, faults
from repro.sketch.session import StreamSession

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_elastic.json")

PHI = 0.005
RESIZE_COLUMNS = ["kind", "dist", "alpha", "ktot", "old_shards",
                  "new_shards", "ms_resize", "moved", "dropped",
                  "error_slack", "recall_before", "recall_after",
                  "precision_before", "precision_after"]
RECOVERY_COLUMNS = ["kind", "shards", "n_blocks", "block", "faults",
                    "ms_recover", "replayed_blocks", "bit_exact",
                    "recall_faulted", "recall_recovered",
                    "precision_faulted", "precision_recovered"]


def _kind_cells(ktot_freq: int, ktot_quant: int):
    """(kind, spec kwargs, stream universe) for both backends."""
    return (
        ("frequency", dict(kind="frequency", k=ktot_freq), 1 << 16),
        ("quantile", dict(kind="quantile", k=ktot_quant, bits=8), 1 << 8),
    )


def _rp(spec, state, freqs):
    cand = np.nonzero(freqs > 0)[0]
    est = np.asarray(jax.device_get(api.query_many(spec, state, cand)),
                     np.float64)
    return recall_precision(None, freqs, PHI, est=est)


def bench_resize(n_insert: int = 20000, old_shards: int = 4,
                 new_counts=(1, 2, 8), runs: int = 5,
                 ktot_freq: int = 1024, ktot_quant: int = 2048):
    rows = []
    alpha = 2.0
    for kind, spec_kw, universe in _kind_cells(ktot_freq, ktot_quant):
        stream = dist_stream("zipf", n_insert, 0.5, order="interleaved",
                             seed=11, universe=universe)
        freqs = exact_freqs(stream, universe)
        spec = api.SketchSpec(shards=old_shards, **spec_kw)
        sess = StreamSession(spec, block=4096)
        sess.extend(stream[:, 0].astype(np.int32),
                    stream[:, 1].astype(np.int32))
        sess.flush()
        rec_b, prec_b = _rp(spec, sess.state, freqs)
        fn = elastic.reshard if kind == "frequency" else elastic.reshard_dyadic
        for new_s in new_counts:
            best = float("inf")
            for _ in range(max(runs, 1)):
                t0 = time.perf_counter()
                new_state, report = fn(sess.state, new_s)
                best = min(best, time.perf_counter() - t0)
            spec2 = dataclasses.replace(spec, shards=new_s)
            rec_a, prec_a = _rp(spec2, new_state, freqs)
            rows.append([kind, "zipf", alpha, spec.k, old_shards, new_s,
                         best * 1e3, report.moved, report.dropped,
                         report.error_slack, rec_b, rec_a, prec_b, prec_a])
    csv_print("elastic_resize", RESIZE_COLUMNS, rows)
    return rows


def bench_recovery(n_blocks: int = 24, block: int = 512,
                   shards: int = 4, ktot_freq: int = 1024,
                   ktot_quant: int = 2048):
    """Fault a live session mid-stream, then rebuild every row from the
    checkpoint + replay log and verify the never-failed twin bit-for-bit
    (the exactly-once guarantee of DESIGN.md §12)."""
    rows = []
    plan = faults.FaultPlan(events=(
        faults.FaultEvent(step=n_blocks // 3, row=2, kind="drop"),
        faults.FaultEvent(step=n_blocks // 2, row=1, kind="corrupt"),
        faults.FaultEvent(step=2 * n_blocks // 3, row=0, kind="duplicate"),
    ))
    for kind, spec_kw, universe in _kind_cells(ktot_freq, ktot_quant):
        stream = dist_stream("zipf", n_blocks * block, 0.0, seed=13,
                             universe=universe)
        items, weights, nb = stream_blocks(stream, block)
        freqs = exact_freqs(stream, universe)
        spec = api.SketchSpec(shards=shards, **spec_kw)
        sess = StreamSession(spec, block=block, replay=2 * n_blocks,
                             fault_plan=plan)
        ref = StreamSession(spec, block=block)
        ckpt = sess.save(include_schedule=True)
        for b in range(nb):
            sl = slice(b * block, (b + 1) * block)
            sess.ingest_block(items[sl], weights[sl])
            ref.ingest_block(items[sl], weights[sl])
        rec_f, prec_f = _rp(spec, sess.state, freqs)
        report = elastic.recover_session(sess, ckpt, rows=range(shards))
        bit_exact = all(
            np.array_equal(np.asarray(jax.device_get(x)),
                           np.asarray(jax.device_get(y)))
            for x, y in zip(jax.tree.leaves(sess.state),
                            jax.tree.leaves(ref.state)))
        rec_r, prec_r = _rp(spec, sess.state, freqs)
        rows.append([kind, shards, nb, block, len(plan.events),
                     report.seconds * 1e3, report.replayed_blocks,
                     bit_exact, rec_f, rec_r, prec_f, prec_r])
    csv_print("elastic_recovery", RECOVERY_COLUMNS, rows)
    return rows


def _write_json(results: dict, path: str = JSON_PATH) -> None:
    write_bench_json(results,
                     {"resize": RESIZE_COLUMNS,
                      "recovery": RECOVERY_COLUMNS},
                     path)


def run(runs: int = 5, write_json: bool = True, smoke: bool = False, **kw):
    if smoke:
        results = {
            "resize": bench_resize(n_insert=2000, new_counts=(2,), runs=1,
                                   ktot_freq=256, ktot_quant=512),
            "recovery": bench_recovery(n_blocks=6, block=128,
                                       ktot_freq=256, ktot_quant=512),
        }
    else:
        results = {
            "resize": bench_resize(runs=runs),
            "recovery": bench_recovery(),
        }
    if write_json and not smoke:
        _write_json(results)
    return results


if __name__ == "__main__":
    run()
