"""Analyzer runtime + coverage bench (BENCH_analysis.json).

The `repro.analysis` gate runs on every push, so its wall time is a CI
tax every PR pays — this bench makes that cost (and the analyzer's
coverage: rules checked, entry points traced, findings) a tracked
artifact next to the perf benches.  A range-pass regression that, say,
loses the scan-unrolling fast path shows up here as a wall-time cliff
before it shows up as a 10-minute CI job.

One row per layer: wall ms, entry points analyzed, findings (expected
0 on a clean tree).  Trends only — 2-core CPU numbers (DESIGN.md §7).
"""
from __future__ import annotations

import os
import time

from benchmarks.common import csv_print, write_bench_json

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_analysis.json")

COLUMNS = ["layer", "rules", "entry_points", "findings", "ms_wall"]


def _layer_rows(k: int, block: int):
    from repro.analysis.astlint import lint_tree
    from repro.analysis.donation_audit import audit_donation
    from repro.analysis.range_interp import DEFAULT_GRID, analyze_ingest_grid
    from repro.analysis.recompile_audit import audit_recompiles, default_grid
    from repro.analysis.sentinel_flow import analyze_query_grid

    rows = []

    t0 = time.perf_counter()
    fs = lint_tree(os.path.join(_REPO_ROOT, "src", "repro"))
    n_files = sum(1 for dp, dn, fn in os.walk(
        os.path.join(_REPO_ROOT, "src", "repro"))
        for f in fn if f.endswith(".py"))
    rows.append(["ast", "SK101-SK104", n_files, len(fs),
                 (time.perf_counter() - t0) * 1e3])

    t0 = time.perf_counter()
    fs = analyze_ingest_grid(k=k, block=block)
    rows.append(["range", "SK201", len(DEFAULT_GRID) + 1, len(fs),
                 (time.perf_counter() - t0) * 1e3])

    t0 = time.perf_counter()
    fs = analyze_query_grid(k=k)
    rows.append(["sentinel", "SK202", len(DEFAULT_GRID) + 1, len(fs),
                 (time.perf_counter() - t0) * 1e3])

    t0 = time.perf_counter()
    fs, report = audit_recompiles(block=block, k=k)
    rows.append(["recompile", "SK203", report["grid"], len(fs),
                 (time.perf_counter() - t0) * 1e3])

    t0 = time.perf_counter()
    fs, _ = audit_donation(k=k, block=block)
    rows.append(["donation", "SK204", 4 + 2, len(fs),
                 (time.perf_counter() - t0) * 1e3])
    return rows


def run(smoke: bool = False, write_json: bool = True,
        k: int | None = None, block: int | None = None) -> None:
    k = k or (16 if smoke else 64)
    block = block or (16 if smoke else 64)
    rows = _layer_rows(k, block)
    csv_print("analysis", COLUMNS, rows)
    total_findings = sum(r[3] for r in rows)
    total_ms = sum(r[4] for r in rows)
    print(f"# total: {total_findings} finding(s), {total_ms:.0f} ms "
          f"across {len(rows)} layers (k={k}, block={block})")
    if total_findings:
        raise AssertionError(
            f"analyzer found {total_findings} finding(s) on the committed "
            f"tree — run PYTHONPATH=src python -m repro.analysis for the "
            f"report")
    if write_json:
        write_bench_json({"analysis": rows}, {"analysis": COLUMNS},
                         JSON_PATH)
        print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    run()
