"""Paper Figs 8-10: DSS± vs DCS vs KLL± — KS divergence vs space,
vs delete ratio, and update time."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_print
from repro.core.quantiles import KLLpm, dyadic_from_budget, ks_divergence
from repro.core.streams import bounded_stream

BITS = 16
UNIVERSE = 1 << BITS


def _run_quantile(sketch, stream: np.ndarray) -> float:
    t0 = time.perf_counter()
    if hasattr(sketch, "process"):
        sketch.process(stream)
    else:
        for item, sign in stream:
            sketch.update(int(item), int(sign))
    return (time.perf_counter() - t0) / len(stream)


def _sketches(budget: int, seed: int):
    return {
        "dss_pm": dyadic_from_budget(BITS, budget, "dss_pm", seed=seed),
        "dcs": dyadic_from_budget(BITS, budget, "dcs", seed=seed),
        "kll_pm": KLLpm(k=max(8, budget // 8), seed=seed),
    }


def _live_values(stream: np.ndarray) -> np.ndarray:
    f = np.zeros(UNIVERSE, np.int64)
    np.add.at(f, stream[:, 0], stream[:, 1])
    return np.repeat(np.nonzero(f)[0], f[np.nonzero(f)[0]])


def run_fig8(n_insert: int = 8000, runs: int = 2, seed0: int = 0):
    rows = []
    for budget in (500, 1000, 2000):
        agg = {}
        for r in range(runs):
            for dist in ("zipf", "binomial", "caida"):
                stream = bounded_stream(dist, n_insert, 0.5,
                                        universe=UNIVERSE, seed=seed0 + r)
                live = _live_values(stream)
                for name, sk in _sketches(budget, seed0 + r).items():
                    _run_quantile(sk, stream)
                    ks = ks_divergence(sk, live)
                    agg.setdefault((dist, name), []).append(ks)
        for (dist, name), vals in agg.items():
            rows.append([dist, budget, name, float(np.mean(vals))])
    csv_print("fig8_quantile_ks_vs_space", ["dist", "budget", "sketch", "ks"], rows)
    return rows


def run_fig9(n_total: int = 8000, runs: int = 2, seed0: int = 0):
    rows = []
    budget = 1000
    for ratio in (0.0, 0.25, 0.5, 0.75, 0.9):
        agg = {}
        n_insert = int(n_total / (1 + ratio))
        for r in range(runs):
            stream = bounded_stream("zipf", n_insert, ratio,
                                    universe=UNIVERSE, seed=seed0 + r)
            live = _live_values(stream)
            for name, sk in _sketches(budget, seed0 + r).items():
                _run_quantile(sk, stream)
                agg.setdefault(name, []).append(ks_divergence(sk, live))
        for name, vals in agg.items():
            rows.append([ratio, name, float(np.mean(vals))])
    csv_print("fig9_quantile_ks_vs_ratio", ["ratio", "sketch", "ks"], rows)
    return rows


def run_fig10(runs: int = 2, seed0: int = 0):
    rows = []
    budget = 1000
    for n in (2000, 4000, 8000):
        agg = {}
        for r in range(runs):
            stream = bounded_stream("zipf", int(n / 1.5), 0.5,
                                    universe=UNIVERSE, seed=seed0 + r)
            for name, sk in _sketches(budget, seed0 + r).items():
                agg.setdefault(name, []).append(_run_quantile(sk, stream))
        for name, vals in agg.items():
            rows.append([n, name, float(np.mean(vals)) * 1e6])
    csv_print("fig10_quantile_update_time", ["stream_len", "sketch", "us"], rows)
    return rows


def run(**kw):
    return {"fig8": run_fig8(), "fig9": run_fig9(), "fig10": run_fig10()}


if __name__ == "__main__":
    run()
