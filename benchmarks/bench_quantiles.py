"""Paper Figs 8-10 + the dyadic-bank throughput story.

Figs 8-10 mirror the paper's §5.5 quantile experiments (DSS± vs DCS vs
KLL±: KS divergence vs space, vs delete ratio, and update time). New
since the JAX dyadic bank landed: per distribution, the python-reference
per-item loop (bits heap updates per element) is raced against the
fused bank-engine path (``path='bank'``: batched dense phase 1 + the
lockstep banked residual loop, one launch for the whole (bits, k) bank
— the production path), the pre-engine vmapped block path
(``block_update_batched``, kept for A/B) and the Pallas banked-kernel
path (one residual launch for the whole bank, interpret mode on CPU),
with KS divergence reported for each so the speedup is provably not
bought with accuracy. The acceptance cell for the bank engine is
(zipf, bits=16, budget=2048): ``bank`` must be ≥1.5× ``jax_block``.
Results land in ``BENCH_quantiles.json`` at the repo root (same
contract as BENCH_kernels.json): machine-readable perf trajectory
across PRs.

Wall-times are CPU interpret-mode numbers — relative trends only
(DESIGN.md §7-§8).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import (
    csv_print,
    run_sketch,
    run_spec,
    session_overhead,
    write_bench_json,
)
from repro.core.quantiles import (
    KLLpm,
    dyadic_from_budget,
    ks_divergence,
    true_ranks,
)
from benchmarks.common import dist_stream, zipf_stream

BITS = 16
UNIVERSE = 1 << BITS

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_quantiles.json")

DYADIC_COLUMNS = ["dist", "bits", "budget", "impl", "block",
                  "updates_per_s", "ks", "speedup_vs_ref"]
SESSION_COLUMNS = ["dist", "bits", "budget", "block", "ms_direct",
                   "ms_session", "overhead_pct"]
FIG8_COLUMNS = ["dist", "budget", "sketch", "ks"]
FIG9_COLUMNS = ["ratio", "sketch", "ks"]
FIG10_COLUMNS = ["stream_len", "sketch", "us"]


def _sketches(budget: int, seed: int):
    return {
        "dss_pm": dyadic_from_budget(BITS, budget, "dss_pm", seed=seed),
        "dcs": dyadic_from_budget(BITS, budget, "dcs", seed=seed),
        "kll_pm": KLLpm(k=max(8, budget // 8), seed=seed),
    }


def _live_values(stream: np.ndarray) -> np.ndarray:
    f = np.zeros(UNIVERSE, np.int64)
    np.add.at(f, stream[:, 0], stream[:, 1])
    return np.repeat(np.nonzero(f)[0], f[np.nonzero(f)[0]])


def run_fig8(n_insert: int = 8000, runs: int = 2, seed0: int = 0):
    rows = []
    for budget in (500, 1000, 2000):
        agg = {}
        for r in range(runs):
            for dist in ("zipf", "binomial", "caida"):
                stream = dist_stream(dist, n_insert, 0.5, seed=seed0 + r)
                live = _live_values(stream)
                for name, sk in _sketches(budget, seed0 + r).items():
                    run_sketch(sk, stream)
                    ks = ks_divergence(sk, live)
                    agg.setdefault((dist, name), []).append(ks)
        for (dist, name), vals in agg.items():
            rows.append([dist, budget, name, float(np.mean(vals))])
    csv_print("fig8_quantile_ks_vs_space", FIG8_COLUMNS, rows)
    return rows


def run_fig9(n_total: int = 8000, runs: int = 2, seed0: int = 0):
    rows = []
    budget = 1000
    for ratio in (0.0, 0.25, 0.5, 0.75, 0.9):
        agg = {}
        n_insert = int(n_total / (1 + ratio))
        for r in range(runs):
            stream = zipf_stream(n_insert, ratio, seed=seed0 + r)
            live = _live_values(stream)
            for name, sk in _sketches(budget, seed0 + r).items():
                run_sketch(sk, stream)
                agg.setdefault(name, []).append(ks_divergence(sk, live))
        for name, vals in agg.items():
            rows.append([ratio, name, float(np.mean(vals))])
    csv_print("fig9_quantile_ks_vs_ratio", FIG9_COLUMNS, rows)
    return rows


def run_fig10(runs: int = 2, seed0: int = 0):
    rows = []
    budget = 1000
    for n in (2000, 4000, 8000):
        agg = {}
        for r in range(runs):
            stream = zipf_stream(int(n / 1.5), 0.5, seed=seed0 + r)
            for name, sk in _sketches(budget, seed0 + r).items():
                agg.setdefault(name, []).append(run_sketch(sk, stream))
        for name, vals in agg.items():
            rows.append([n, name, float(np.mean(vals)) * 1e6])
    csv_print("fig10_quantile_update_time", FIG10_COLUMNS, rows)
    return rows


# ---------------------------------------------------------------------------
# Dyadic bank: python reference vs JAX block vs Pallas kernel
# ---------------------------------------------------------------------------

def _ks_dyadic_jax(state, live: np.ndarray, num_queries: int = 128) -> float:
    """KS divergence for the JAX bank: one rank_many call over the grid."""
    import jax.numpy as jnp
    from repro.sketch import dyadic

    qs = np.unique(np.quantile(live, np.linspace(0, 1, num_queries))
                   .astype(np.int64))
    tr = true_ranks(live, qs)
    est = np.asarray(
        dyadic.rank_many(state, jnp.asarray(qs, jnp.int32)), np.float64)
    return float(np.max(np.abs(est - tr)) / len(live))


def _time_jax_path(bits, budget, stream, block, path, variant=2, runs=3):
    """Min-of-N seconds for a full feed (post-compile) + the final state.

    Min-of-N (matching bench_kernels) because CPU-contention outliers at
    the tens-of-ms scale would otherwise dominate a single measurement.
    """
    from repro.sketch import dyadic

    # warmup: compile the (bits, k, block) cell on a fresh state
    dyadic.process_stream(
        dyadic.init(bits, total_counters=budget),
        stream[:block, 0], stream[:block, 1], variant=variant,
        block=block, path=path,
    ).bank.ids.block_until_ready()
    best = float("inf")
    for _ in range(runs):
        st = dyadic.init(bits, total_counters=budget)
        t0 = time.perf_counter()
        st = dyadic.process_stream(st, stream[:, 0], stream[:, 1],
                                   variant=variant, block=block, path=path)
        st.bank.ids.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best, st


def run_dyadic(n_insert: int = 6000, budget: int = 2048, block: int = 2048,
               seed0: int = 0):
    """The BENCH_quantiles.json headline table: updates/s and KS per impl."""
    rows = []
    for dist in ("zipf", "binomial", "caida"):
        stream = dist_stream(dist, n_insert, 0.5, seed=seed0)
        live = _live_values(stream)
        n = len(stream)

        ref = dyadic_from_budget(BITS, budget, "dss_pm", seed=seed0)
        spu = run_sketch(ref, stream)  # sec per update
        ref_ups = 1.0 / spu
        rows.append([dist, BITS, budget, "python_ref", 1,
                     ref_ups, ks_divergence(ref, live), 1.0])

        for impl, path in (("bank", "bank"), ("jax_block", "block"),
                           ("pallas_kernel", "kernel")):
            dt, st = _time_jax_path(BITS, budget, stream, block, path)
            ups = n / dt
            rows.append([dist, BITS, budget, impl, block,
                         ups, _ks_dyadic_jax(st, live), ups / ref_ups])

        # the spec-driven session over the same bank path: same KS (it IS
        # the same math), throughput within session-overhead of 'bank'
        from repro.sketch import api
        spec = api.SketchSpec(kind="quantile", bits=BITS, k=budget,
                              backend="bank")
        dt, sess = run_spec(spec, stream, block)
        ups = n / dt
        rows.append([dist, BITS, budget, "session", block, ups,
                     _ks_dyadic_jax(sess.state, live), ups / ref_ups])
    csv_print("dyadic_update_throughput", DYADIC_COLUMNS, rows)
    return rows


def run_session_overhead(budget: int = 2048, block: int = 2048,
                         n_blocks: int = 16, runs: int = 9, seed0: int = 0):
    """StreamSession dispatch overhead vs the raw fused engine launch at
    the headline zipf cell (DESIGN.md §11: <5% required).

    Direct = ``bank.update_block_fused`` with the level router + the
    exact-mass add; session = the cached jitted ingest for the same
    spec. Both feed the SAME evolving block sequence, so the gap is
    pure session overhead.
    """
    import jax
    from repro.sketch import api, bank as bkmod, dyadic

    stream = dist_stream("zipf", (n_blocks + 1) * block, 0.0, seed=seed0)
    spec = api.SketchSpec(kind="quantile", bits=BITS, k=budget,
                          backend="bank")
    router = bkmod.DyadicLevelRouter(BITS)
    direct = jax.jit(lambda s_, i, w: dyadic.DyadicState(
        bank=bkmod.update_block_fused(s_.bank, i, w, router,
                                      spec.variant_id),
        mass=s_.mass + w.sum()))
    warm = lambda i, w: dyadic.update_block(
        dyadic.init(BITS, total_counters=budget), i, w)
    t_d, t_s, pct = session_overhead(spec, direct, warm, stream, block,
                                     n_blocks, runs)
    rows = [["zipf", BITS, budget, block, t_d / n_blocks * 1e3,
             t_s / n_blocks * 1e3, pct]]
    csv_print("session_overhead", SESSION_COLUMNS, rows)
    return rows


def _write_json(results: dict, path: str = JSON_PATH) -> None:
    write_bench_json(results, {
        "dyadic_update": DYADIC_COLUMNS,
        "session_overhead": SESSION_COLUMNS,
        "fig8": FIG8_COLUMNS,
        "fig9": FIG9_COLUMNS,
        "fig10": FIG10_COLUMNS,
    }, path)


def run(smoke: bool = False, write_json: bool = True, **kw):
    if smoke:
        results = {
            "dyadic_update": run_dyadic(n_insert=1200, budget=256, block=512),
            "session_overhead": run_session_overhead(
                budget=256, block=512, n_blocks=2, runs=2),
            "fig8": run_fig8(n_insert=1000, runs=1),
            "fig9": run_fig9(n_total=1500, runs=1),
            "fig10": run_fig10(runs=1),
        }
    else:
        results = {
            "dyadic_update": run_dyadic(),
            "session_overhead": run_session_overhead(),
            "fig8": run_fig8(),
            "fig9": run_fig9(),
            "fig10": run_fig10(),
        }
    if write_json and not smoke:
        _write_json(results)
    return results


if __name__ == "__main__":
    run()
