"""Paper Fig 5: MSE vs delete:insert ratio at fixed space.

The paper's headline claim: SS± stays the most accurate up to ratio
(logU-1)/logU ~ 0.94 for U = 2^16 while using the same space.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_print, exact_freqs, make_sketches, mse, run_sketch, zipf_stream

RATIOS = (0.0, 0.25, 0.5, 0.75, 0.9, 0.9375)


def run(n_total: int = 200000, runs: int = 2, seed0: int = 0):
    rows = []
    budget = 500  # the paper's "500 logU bits" per sketch
    for ratio in RATIOS:
        alpha = 1.0 / (1.0 - ratio) if ratio < 1 else 16.0
        n_insert = int(n_total / (1 + ratio))
        agg = {}
        for r in range(runs):
            stream = zipf_stream(n_insert, ratio, seed=seed0 + r)
            freqs = exact_freqs(stream)
            sample = np.nonzero(freqs > 0)[0]
            sketches = make_sketches(budget, alpha, n_stream=len(stream),
                                     seed=seed0 + r)
            for name, sk in sketches.items():
                run_sketch(sk, stream)
                agg.setdefault(name, []).append(mse(sk, freqs, sample))
        for name, vals in agg.items():
            rows.append([ratio, name, float(np.mean(vals))])
    csv_print("fig5_mse_vs_delete_ratio", ["ratio", "sketch", "mse"], rows)
    # the paper's claim at ratio <= 0.9375: SS± most accurate
    by_ratio = {}
    for ratio, name, m in rows:
        by_ratio.setdefault(ratio, {})[name] = m
    for ratio, d in by_ratio.items():
        best = min(d, key=d.get)
        print(f"ratio={ratio}: best={best}")
    return rows


if __name__ == "__main__":
    run()
