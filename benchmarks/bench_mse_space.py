"""Paper Fig 4: MSE vs sketch size across distributions and delete
patterns (shuffled/random vs targeted), delete:insert ratio 0.5."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    DISTRIBUTIONS, csv_print, dist_stream, exact_freqs, make_sketches, mse,
    run_sketch,
)


def run(n_insert: int = 100000, runs: int = 2, seed0: int = 0):
    rows = []
    alpha = 2.0  # ratio 0.5
    for dist in DISTRIBUTIONS:
        for pattern in ("random", "targeted"):
            for budget in (200, 500, 1000, 2000):
                agg = {}
                for r in range(runs):
                    stream = dist_stream(dist, n_insert, 0.5,
                                         delete_pattern=pattern,
                                         seed=seed0 + r)
                    freqs = exact_freqs(stream)
                    sample = np.nonzero(freqs > 0)[0]
                    sketches = make_sketches(budget, alpha, n_stream=len(stream),
                                             seed=seed0 + r)
                    for name, sk in sketches.items():
                        run_sketch(sk, stream)
                        agg.setdefault(name, []).append(mse(sk, freqs, sample))
                for name, vals in agg.items():
                    rows.append([dist, pattern, budget, name, float(np.mean(vals))])
    csv_print("fig4_mse_vs_space", ["dist", "pattern", "budget", "sketch", "mse"], rows)
    return rows


if __name__ == "__main__":
    run()
