"""Paper Fig 6: per-update latency vs stream length.

Adds the TPU-adapted JAX paths (scan-exact and block-weighted) and the
Pallas kernel (interpret mode) next to the paper's CPU two-heap
implementation — the update-time story of DESIGN.md §3.
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import csv_print, make_sketches, run_sketch, zipf_stream
from repro import sketch as js

LENGTHS = (5000, 10000, 20000)


def _time_jax_block(stream: np.ndarray, capacity: int, block: int = 4096,
                    update_fn=js.block_update) -> float:
    state = js.init(capacity)
    items = jnp.asarray(stream[:, 0], jnp.int32)
    weights = jnp.asarray(stream[:, 1], jnp.int32)
    # warm up compile
    update_fn(state, items[:block], weights[:block]).ids.block_until_ready()
    t0 = time.perf_counter()
    for s in range(0, len(stream) - block + 1, block):
        state = update_fn(state, items[s : s + block], weights[s : s + block])
    state.ids.block_until_ready()
    return (time.perf_counter() - t0) / max(len(stream) - len(stream) % block, 1)


def run(runs: int = 2, seed0: int = 0, smoke: bool = False):
    lengths = (3000,) if smoke else LENGTHS
    rows = []
    budget, alpha = 500, 2.0
    for n in lengths:
        agg = {}
        for r in range(runs):
            stream = zipf_stream(int(n / 1.5), 0.5, seed=seed0 + r)
            sketches = make_sketches(budget, alpha, n_stream=len(stream), seed=seed0 + r)
            for name, sk in sketches.items():
                agg.setdefault(name, []).append(run_sketch(sk, stream))
            # two-phase monitored-first block path vs the serial-scan
            # baseline (DESIGN.md §3: the A/B for the blocked update)
            agg.setdefault("sspm_jax_block", []).append(
                _time_jax_block(stream, budget)
            )
            agg.setdefault("sspm_jax_block_serial", []).append(
                _time_jax_block(stream, budget, update_fn=js.block_update_serial)
            )
        for name, vals in agg.items():
            rows.append([n, name, float(np.mean(vals)) * 1e6])
    csv_print("fig6_update_time", ["stream_len", "sketch", "us_per_update"], rows)
    return rows


if __name__ == "__main__":
    run()
