"""Benchmark orchestrator: one bench per paper table/figure + kernels +
the sharded-bank scaling bench + the dry-run/roofline summary.

    PYTHONPATH=src python -m benchmarks.run            # full CI suite
    PYTHONPATH=src python -m benchmarks.run --only fig5
    PYTHONPATH=src python -m benchmarks.run --smoke    # bitrot guard: tiny
                                                       # shapes, no JSON
"""
from __future__ import annotations

import argparse
import sys
import time


def _roofline_summary():
    from pathlib import Path
    from repro.roofline.report import (
        load_records, markdown_table, sketch_kernel_table,
    )

    kj = Path("BENCH_kernels.json")
    if kj.exists():
        print("\n# sketch_ingest_roofline (BENCH_kernels.json)")
        print(sketch_kernel_table(kj))
    d = Path("experiments/dryrun")
    if not d.exists() or not list(d.glob("*__single.json")):
        print("# roofline: no dry-run artifacts found "
              "(run python -m repro.launch.dryrun --all); skipping")
        return
    recs = load_records(d, "single")
    print(f"\n# roofline_summary ({len(recs)} single-pod cells)")
    print(markdown_table(recs))


BENCHES = {
    "fig4": ("benchmarks.bench_mse_space", "Fig 4: MSE vs space"),
    "fig5": ("benchmarks.bench_delete_ratio", "Fig 5: MSE vs delete ratio"),
    "fig6": ("benchmarks.bench_update_time", "Fig 6: update time"),
    "fig7": ("benchmarks.bench_recall_precision", "Fig 7: recall/precision"),
    "quantiles": ("benchmarks.bench_quantiles",
                  "Figs 8-10 + dyadic bank throughput (BENCH_quantiles.json)"),
    "kernels": ("benchmarks.bench_kernels",
                "Pallas kernel parity/time + fused-vs-split race + "
                "sketch-ingest roofline (BENCH_kernels.json)"),
    "sharded": ("benchmarks.bench_sharded",
                "hash-sharded bank vs single sketch (BENCH_sharded.json)"),
    "elastic": ("benchmarks.bench_elastic",
                "live resize + fault recovery (BENCH_elastic.json)"),
    "compression": ("benchmarks.bench_compression", "grad compression bytes"),
    "h2o": ("benchmarks.bench_h2o_quality", "SS± KV-cache retention quality"),
    "family": ("benchmarks.bench_family",
               "SS± family frontier: double/unbiased/crprecis "
               "(BENCH_family.json)"),
    "service": ("benchmarks.bench_service",
                "multi-tenant service: heavy-traffic day, fused-vs-"
                "sessions race + roofline (BENCH_service.json)"),
    "analysis": ("benchmarks.bench_analysis",
                 "repro.analysis gate: rule counts + wall per layer "
                 "(BENCH_analysis.json)"),
}

# --smoke shape overrides: every bench still executes end to end (import,
# trace, compile, report) so bitrot fails CI, but at seconds-scale sizes
# and with JSON artifacts suppressed. Benches without size knobs already
# run at smoke scale (h2o decodes a smoke config; compression emulates 8
# CPU devices on tiny grads).
SMOKE_KW = {
    "fig4": dict(n_insert=2000, runs=1),
    "fig5": dict(n_total=4000, runs=1),
    "fig6": dict(runs=1, smoke=True),
    "fig7": dict(n_insert=2000, runs=1),
    "quantiles": dict(smoke=True, write_json=False),
    "kernels": dict(smoke=True, write_json=False),
    "sharded": dict(smoke=True, write_json=False),
    "elastic": dict(smoke=True, write_json=False),
    "compression": {},
    "h2o": {},
    "family": dict(smoke=True, write_json=False),
    "service": dict(smoke=True, write_json=False),
    "analysis": dict(smoke=True, write_json=False),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no JSON artifacts (CI bitrot guard)")
    args = ap.parse_args()

    names = [args.only] if args.only else list(BENCHES)
    t_all = time.time()
    failed = []
    for name in names:
        mod_name, desc = BENCHES[name]
        print(f"\n{'='*70}\n== {name}: {desc}\n{'='*70}", flush=True)
        t0 = time.time()
        if name == "compression":
            # needs emulated devices: run in a subprocess with XLA_FLAGS
            # so this process keeps its single-device view
            import os
            import subprocess

            from repro.platform import xla_host_device_flags
            env = dict(os.environ)
            env["XLA_FLAGS"] = xla_host_device_flags(8)
            out = subprocess.run(
                [sys.executable, "-m", mod_name], env=env,
                capture_output=True, text=True, timeout=600,
            )
            print(out.stdout)
            if out.returncode != 0:
                print(out.stderr[-1500:])
                failed.append(name)
        else:
            # one failing bench must neither abort the remaining benches
            # nor let the manifest loop exit 0 — record it and keep going
            # (the subprocess test in tests/test_bench_run.py pins this).
            try:
                mod = __import__(mod_name, fromlist=["run"])
                mod.run(**(SMOKE_KW[name] if args.smoke else {}))
            except Exception:
                import traceback

                traceback.print_exc()
                failed.append(name)
        status = "FAILED" if name in failed else "done"
        print(f"== {name} {status} in {time.time()-t0:.1f}s", flush=True)
    _roofline_summary()
    if failed:
        print(f"\nFAILED benches: {', '.join(failed)} "
              f"({time.time()-t_all:.1f}s)")
        return 1
    print(f"\nall benchmarks done in {time.time()-t_all:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
