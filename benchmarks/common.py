"""Shared helpers for the paper-experiment benchmarks.

Scaled defaults: the paper uses |F|1 = 1e5 (synthetic) / 1e6 (CAIDA)
averaged over 5 runs; CI defaults here are 2e4 / 3 runs so the whole
suite stays minutes on one CPU core. ``--full`` restores paper scale.
Trends, not absolute values, are the comparison target (DESIGN.md §7).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List, Optional

import numpy as np

from repro.core.baselines import CSSS, CountMedian, CountMin
from repro.core.spacesaving import LazySpaceSavingPM, SpaceSavingPM
from repro.core.streams import bounded_stream

DISTRIBUTIONS = ("zipf", "binomial", "caida")
UNIVERSE = 1 << 16
UNIVERSE_BITS = 16


def dist_stream(dist: str, n_insert: int, delete_ratio: float = 0.5,
                *, seed: int = 0, universe: int = UNIVERSE,
                delete_pattern: str = "random",
                order: str = "inserts_first") -> np.ndarray:
    """The one bounded-deletion stream factory every bench shares.

    Thin front-end over ``repro.core.streams.bounded_stream`` pinning the
    benchmarks' common universe so scripts stop re-spelling the same
    kwargs (and silently diverging on them).
    """
    return bounded_stream(dist, n_insert, delete_ratio, universe=universe,
                          delete_pattern=delete_pattern, order=order,
                          seed=seed)


def zipf_stream(n_insert: int, delete_ratio: float = 0.5, *, seed: int = 0,
                order: str = "inserts_first") -> np.ndarray:
    """Zipf marginal (the paper's synthetic default, §5.2)."""
    return dist_stream("zipf", n_insert, delete_ratio, seed=seed, order=order)


def adversarial_stream(n_insert: int, delete_ratio: float = 0.5,
                       *, seed: int = 0) -> np.ndarray:
    """The paper's adversarial case: targeted deletions, inserts first.

    Deleting the heaviest monitored items maximizes unmonitored-deletion
    spreading — the locality-minimizing worst case for SS± (§5.3).
    """
    return dist_stream("zipf", n_insert, delete_ratio, seed=seed,
                       delete_pattern="targeted", order="inserts_first")


def mixed_traffic(num_tenants: int, n_updates: int, *,
                  delete_ratio: float = 0.5, skew: float = 1.2,
                  query_frac: float = 0.1, query_size: int = 8,
                  burst: int = 64, dist: str = "zipf",
                  universe: int = UNIVERSE, seed: int = 0) -> List[tuple]:
    """A heavy-traffic day in op form: the shared multi-tenant generator.

    Returns a seeded, reproducible list of interleaved ops

        ("update", tenant, items, weights)   signed int32 fragments
        ("query",  tenant, items)            point-query probes

    Tenant sizes are zipf-skewed (tenant ranks weighted ``(r+1)^-skew``,
    sizes drawn multinomially so they sum to ``n_updates``): a few whale
    tenants, a long tail — the service bench's population shape. Each
    tenant's own substream is a standard ``dist_stream`` bounded-deletion
    stream (per-tenant seed), chopped into ``burst``-sized update ops;
    after a burst, with probability ``query_frac``, a query op probes
    ``query_size`` items drawn from that burst. The global interleaving
    permutes ops ACROSS tenants while preserving each tenant's own op
    order (a fixed-permutation label trick), so per-tenant
    insert-before-delete validity survives the shuffle.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, num_tenants + 1, dtype=np.float64)
    p = ranks ** -float(skew)
    p /= p.sum()
    sizes = rng.multinomial(int(n_updates), p)
    per_tenant_ops: List[List[tuple]] = []
    for t in range(num_tenants):
        ops_t: List[tuple] = []
        if sizes[t] > 0:
            sub = dist_stream(dist, int(sizes[t]), delete_ratio,
                              seed=seed + 7919 * t, universe=universe)
            for s in range(0, len(sub), burst):
                chunk = sub[s:s + burst]
                items = np.ascontiguousarray(chunk[:, 0], np.int32)
                weights = np.ascontiguousarray(chunk[:, 1], np.int32)
                ops_t.append(("update", t, items, weights))
                if rng.random() < query_frac:
                    probes = rng.choice(items, size=min(query_size,
                                                        len(items)))
                    ops_t.append(("query", t, probes.astype(np.int32)))
        per_tenant_ops.append(ops_t)
    labels = np.repeat(np.arange(num_tenants),
                       [len(o) for o in per_tenant_ops])
    rng.shuffle(labels)
    cursors = [0] * num_tenants
    out: List[tuple] = []
    for t in labels:
        out.append(per_tenant_ops[t][cursors[t]])
        cursors[t] += 1
    return out


def stream_blocks(stream: np.ndarray, block: int):
    """(items, weights) int32 arrays zero-padded to a multiple of block."""
    n = len(stream)
    nb = max(1, -(-n // block))
    items = np.zeros(nb * block, np.int32)
    weights = np.zeros(nb * block, np.int32)
    items[:n] = stream[:, 0]
    weights[:n] = stream[:, 1]
    return items, weights, nb


def exact_freqs(stream: np.ndarray, universe: int = UNIVERSE) -> np.ndarray:
    f = np.zeros(universe, np.int64)
    np.add.at(f, stream[:, 0], stream[:, 1])
    return f


def run_sketch(sketch, stream: np.ndarray) -> float:
    """Feed the stream; returns seconds per update."""
    t0 = time.perf_counter()
    if hasattr(sketch, "process"):
        sketch.process(stream)
    else:
        for item, sign in stream:
            sketch.update(int(item), int(sign))
    return (time.perf_counter() - t0) / len(stream)


def mse(sketch, freqs: np.ndarray, sample: np.ndarray) -> float:
    if hasattr(sketch, "query_many"):
        est = np.asarray(sketch.query_many(sample), dtype=np.float64)
    else:
        est = np.asarray([sketch.query(int(i)) for i in sample], dtype=np.float64)
    return float(np.mean((est - freqs[sample]) ** 2))


def recall_precision(sketch, freqs: np.ndarray, phi: float,
                     est: Optional[np.ndarray] = None):
    """phi-heavy-hitter recall/precision vs exact ``freqs``.

    ``est``: optional precomputed estimates aligned with the nonzero
    candidates of ``freqs`` — callers that already ran query_many (e.g.
    bench_sharded, which reuses one estimate vector across phis) pass it
    to skip the per-sketch query here.
    """
    live = freqs.sum()
    thresh = phi * live
    true_hot = set(np.nonzero(freqs >= thresh)[0].tolist())
    cand = np.nonzero(freqs > 0)[0]
    if est is None:
        if hasattr(sketch, "query_many"):
            est = np.asarray(sketch.query_many(cand), dtype=np.float64)
        else:
            est = np.asarray([sketch.query(int(i)) for i in cand],
                             dtype=np.float64)
    reported = set(cand[est >= thresh].tolist())
    tp = len(true_hot & reported)
    recall = tp / max(len(true_hot), 1)
    precision = tp / max(len(reported), 1)
    return recall, precision


def make_sketches(budget: int, alpha: float, universe: int = UNIVERSE,
                  n_stream: int = 0, seed: int = 0) -> Dict[str, object]:
    """The paper's §5 lineup at EQUAL space (``budget`` counters each).

    This mirrors the paper's Fig 5 setup ("the sketch space is 500 logU
    bits" for every sketch): SS± variants spend the budget on k counters;
    Count-Min / Count-Median arrange the same counter budget as
    depth x width with the customary depth 5; CSSS runs its sampling
    front-end over an equally-sized Count-Median.
    """
    depth = 5
    width = max(2, budget // depth)
    eps_implied = alpha / budget
    return {
        "lazy_sspm": LazySpaceSavingPM(capacity=budget),
        "sspm": SpaceSavingPM(capacity=budget),
        "count_min": CountMin(width=width, depth=depth, seed=seed),
        "count_median": CountMedian(width=width, depth=depth, seed=seed),
        "csss": CSSS(eps=eps_implied, delta=1.0 / universe, alpha=alpha,
                     universe=universe, stream_len=max(n_stream, 1000),
                     seed=seed, sample_const=4.0),
    }


def min_time(fn: Callable, runs: int) -> float:
    """Min-of-N wall time of a jitted callable returning a JAX pytree.

    One warmup call (compile), then min over ``runs`` — robust to the
    CPU-contention outliers that would dominate a mean at the ms scale.
    Shared by the kernel/sharded benches (was duplicated per script).
    """
    import jax

    def ready(out):
        jax.tree.map(lambda x: x.block_until_ready(), out)

    ready(fn())
    best = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run_spec(spec, stream: np.ndarray, block: int, *, runs: int = 3,
             window=None):
    """The one spec-driven bench driver: feed ``stream`` through a fresh
    :class:`repro.sketch.StreamSession` per run, min-of-N seconds.

    Replaces the per-script pad-and-feed loops: any (kind × shards ×
    variant × backend) cell is one ``SketchSpec`` away.  Returns
    ``(best_seconds, final_session)`` — callers query the session for
    accuracy metrics so the timed path is exactly the production path.
    """
    from repro.sketch.session import StreamSession

    items = np.ascontiguousarray(stream[:, 0], np.int32)
    weights = np.ascontiguousarray(stream[:, 1], np.int32)

    def one_pass():
        s = StreamSession(spec, block=block, window=window)
        s.extend(items, weights)
        s.flush()
        jax_block_until_ready(s.state)
        return s

    sess = one_pass()  # warmup: compile every (spec, block) shape
    best = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        sess = one_pass()
        best = min(best, time.perf_counter() - t0)
    return best, sess


def jax_block_until_ready(tree) -> None:
    import jax

    jax.tree.map(lambda x: x.block_until_ready(), tree)


def session_overhead(spec, direct_fn, warm_fn, stream: np.ndarray,
                     block: int, n_blocks: int, runs: int = 5):
    """Race StreamSession.ingest_block against the direct engine call on
    the SAME evolving state sequence (bit-identical work), min-of-N.

    ``direct_fn(state, items, weights) -> state`` is the raw jitted
    spelling (e.g. ``bank.update_block_fused`` with a pinned router);
    ``warm_fn(items, weights) -> state`` builds the warm start from the
    stream's first block; the session runs its cached jitted ingest for
    the same spec.  Because both loops visit identical states, the
    difference is pure session dispatch/buffer overhead — the <5%
    acceptance number of DESIGN.md §11 (the shared scaffolding of both
    session-overhead bench cells).  Returns
    (sec_direct, sec_session, overhead_pct), both times over the whole
    ``n_blocks`` sequence.
    """
    import jax
    import jax.numpy as jnp

    from repro.sketch.session import StreamSession

    def cut(col, b):
        return jnp.asarray(stream[b * block:(b + 1) * block, col], jnp.int32)

    warm_state = warm_fn(cut(0, 0), cut(1, 0))
    blocks_i = [cut(0, b) for b in range(1, n_blocks + 1)]
    blocks_w = [cut(1, b) for b in range(1, n_blocks + 1)]

    def fresh_state():
        # per-pass buffer copy: the session's compiled ingest donates its
        # state on accelerators, so reusing warm_state across passes would
        # hit deleted buffers there; copy on both sides for symmetry.
        return jax.tree.map(lambda x: x.copy(), warm_state)

    def run_direct():
        st = fresh_state()
        for i, w in zip(blocks_i, blocks_w):
            st = direct_fn(st, i, w)
        jax_block_until_ready(st)
        return st

    def run_session():
        s = StreamSession(spec, block=block, state=fresh_state())
        for i, w in zip(blocks_i, blocks_w):
            s.ingest_block(i, w)
        jax_block_until_ready(s.state)
        return s.state

    # interleave the trials: contended CPUs drift over a bench process's
    # lifetime, and back-to-back min_time blocks would charge that drift
    # entirely to whichever side runs second.
    run_direct()                             # compile both sides first
    run_session()
    t_direct = t_session = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        run_direct()
        t_direct = min(t_direct, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_session()
        t_session = min(t_session, time.perf_counter() - t0)
    return t_direct, t_session, 100.0 * (t_session - t_direct) / t_direct


def _json_default(obj):
    """np scalars -> python; anything else is a bug, not a bool."""
    if isinstance(obj, np.generic):
        return obj.item()
    raise TypeError(f"not JSON-serializable: {type(obj).__name__}")


def write_bench_json(results: Dict[str, list], columns: Dict[str, List[str]],
                     path: str) -> None:
    """The BENCH_*.json artifact contract: one table per key, rows as
    column-name dicts (machine-readable perf trajectory across PRs)."""
    import json

    payload = {
        name: [dict(zip(cols, r)) for r in results[name]]
        for name, cols in columns.items() if name in results
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_json_default)
        f.write("\n")
    print(f"\n# wrote {path}")


def csv_print(name: str, header: List[str], rows: Iterable[Iterable]) -> None:
    print(f"\n# {name}")
    print(",".join(header))
    for r in rows:
        print(",".join(f"{x:.6g}" if isinstance(x, float) else str(x) for x in r))
