"""Shared helpers for the paper-experiment benchmarks.

Scaled defaults: the paper uses |F|1 = 1e5 (synthetic) / 1e6 (CAIDA)
averaged over 5 runs; CI defaults here are 2e4 / 3 runs so the whole
suite stays minutes on one CPU core. ``--full`` restores paper scale.
Trends, not absolute values, are the comparison target (DESIGN.md §7).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import numpy as np

from repro.core.baselines import CSSS, CountMedian, CountMin
from repro.core.spacesaving import LazySpaceSavingPM, SpaceSavingPM
from repro.core.streams import bounded_stream

DISTRIBUTIONS = ("zipf", "binomial", "caida")
UNIVERSE = 1 << 16


def exact_freqs(stream: np.ndarray, universe: int = UNIVERSE) -> np.ndarray:
    f = np.zeros(universe, np.int64)
    np.add.at(f, stream[:, 0], stream[:, 1])
    return f


def run_sketch(sketch, stream: np.ndarray) -> float:
    """Feed the stream; returns seconds per update."""
    t0 = time.perf_counter()
    if hasattr(sketch, "process"):
        sketch.process(stream)
    else:
        for item, sign in stream:
            sketch.update(int(item), int(sign))
    return (time.perf_counter() - t0) / len(stream)


def mse(sketch, freqs: np.ndarray, sample: np.ndarray) -> float:
    if hasattr(sketch, "query_many"):
        est = np.asarray(sketch.query_many(sample), dtype=np.float64)
    else:
        est = np.asarray([sketch.query(int(i)) for i in sample], dtype=np.float64)
    return float(np.mean((est - freqs[sample]) ** 2))


def recall_precision(sketch, freqs: np.ndarray, phi: float):
    live = freqs.sum()
    thresh = phi * live
    true_hot = set(np.nonzero(freqs >= thresh)[0].tolist())
    cand = np.nonzero(freqs > 0)[0]
    if hasattr(sketch, "query_many"):
        est = np.asarray(sketch.query_many(cand), dtype=np.float64)
    else:
        est = np.asarray([sketch.query(int(i)) for i in cand], dtype=np.float64)
    reported = set(cand[est >= thresh].tolist())
    tp = len(true_hot & reported)
    recall = tp / max(len(true_hot), 1)
    precision = tp / max(len(reported), 1)
    return recall, precision


def make_sketches(budget: int, alpha: float, universe: int = UNIVERSE,
                  n_stream: int = 0, seed: int = 0) -> Dict[str, object]:
    """The paper's §5 lineup at EQUAL space (``budget`` counters each).

    This mirrors the paper's Fig 5 setup ("the sketch space is 500 logU
    bits" for every sketch): SS± variants spend the budget on k counters;
    Count-Min / Count-Median arrange the same counter budget as
    depth x width with the customary depth 5; CSSS runs its sampling
    front-end over an equally-sized Count-Median.
    """
    depth = 5
    width = max(2, budget // depth)
    eps_implied = alpha / budget
    return {
        "lazy_sspm": LazySpaceSavingPM(capacity=budget),
        "sspm": SpaceSavingPM(capacity=budget),
        "count_min": CountMin(width=width, depth=depth, seed=seed),
        "count_median": CountMedian(width=width, depth=depth, seed=seed),
        "csss": CSSS(eps=eps_implied, delta=1.0 / universe, alpha=alpha,
                     universe=universe, stream_len=max(n_stream, 1000),
                     seed=seed, sample_const=4.0),
    }


def csv_print(name: str, header: List[str], rows: Iterable[Iterable]) -> None:
    print(f"\n# {name}")
    print(",".join(header))
    for r in rows:
        print(",".join(f"{x:.6g}" if isinstance(x, float) else str(x) for x in r))
