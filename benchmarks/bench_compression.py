"""Gradient-compression benchmark: collective-bytes reduction in HLO.

Lowers the dense psum vs the top-k compressed exchange on an emulated
8-device mesh (subprocess-free: this bench runs as its own process via
benchmarks.run, which sets the device count) and reports the parsed
collective bytes — the distributed-optimization trick's receipt.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_print


def run(**kw):
    import jax
    if len(jax.devices()) < 8:
        print("# bench_compression: needs 8 emulated devices "
              "(run via benchmarks.run --compression or set XLA_FLAGS); skipping")
        return []
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.roofline.hlo import collective_bytes
    from repro.train.dp_exchange import build_compressed_allreduce

    mesh = jax.make_mesh((8,), ("data",))
    n = 1 << 20
    g = {"w": jnp.zeros((n,), jnp.float32)}
    r = {"w": jnp.zeros((n,), jnp.float32)}

    def dense(grads):
        return shard_map(
            lambda t: jax.tree.map(lambda x: jax.lax.psum(x, "data"), t),
            mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), grads),),
            out_specs=jax.tree.map(lambda _: P(), grads), check_rep=False,
        )(grads)

    comp = build_compressed_allreduce(mesh, k_frac=0.01)

    rows = []
    for name, fn, args in (
        ("dense_psum", dense, (g,)),
        ("topk_1pct", comp, (g, r)),
    ):
        lowered = jax.jit(fn).lower(*args)
        hlo = lowered.compile().as_text()
        cb = collective_bytes(hlo, scan_corrected=False)
        rows.append([name, cb["all-reduce"], cb["all-gather"], cb["total"]])
    csv_print(
        "compression_collective_bytes",
        ["exchange", "all_reduce_B", "all_gather_B", "total_B"],
        rows,
    )
    if len(rows) == 2 and rows[1][3] > 0:
        print(f"# reduction: {rows[0][3] / rows[1][3]:.1f}x fewer collective bytes")
    return rows


if __name__ == "__main__":
    run()
