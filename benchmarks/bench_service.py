"""Multi-tenant service bench: a heavy-traffic day on one fused bank.

Three tables into BENCH_service.json:

  * ``service`` — replay a zipf-skewed mixed insert/delete/query day
    (``common.mixed_traffic``) through :class:`repro.serve.SketchService`
    at >= 1000 tenants, per delete ratio: sustained updates/sec through
    the coalesced tick loop, batched point-query throughput (one
    owner-row gather), p99 per-ticket query latency, a sampled-row
    serial-reference parity bill, and the compiled-ingest cache growth
    (the one-compile-per-layout satellite: every tenant layout of the
    day shares ONE compiled ingest).
  * ``fused_vs_sessions`` — the tentpole race: the SAME per-tenant
    traffic at EQUAL total counter budget through (a) one multi-tenant
    fused bank vs (b) one ``StreamSession`` per tenant; the acceptance
    bar is fused >= 2x. A separate untimed pass pins bit-identity of a
    sampled tenant subset against independently-fed per-tenant sketches.
  * ``roofline`` — the fused multi-tenant block held to the same
    achieved-vs-peak standard as BENCH_kernels.json
    (``roofline.sketch_ingest_cost`` at the service's (T*S, k_row,
    block) shape).

Parity is SAMPLED here (rows are independent under the partition
router, so per-row parity is exact evidence, and tests/test_tenant.py
pins the exhaustive small-scale grid); the sample size is a column, not
a hidden cap.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    UNIVERSE_BITS,
    csv_print,
    min_time,
    mixed_traffic,
    write_bench_json,
)

COLUMNS = {
    "service": [
        "tenants", "shards", "delete_ratio", "updates", "queries",
        "blocks", "updates_per_s", "batched_queries_per_s", "p99_query_ms",
        "parity_rows", "parity_ok", "cache_entries_added",
    ],
    "fused_vs_sessions": [
        "tenants", "k_per_tenant", "total_budget", "block",
        "updates", "ms_fused", "ms_sessions", "speedup",
        "parity_tenants", "bit_identical",
    ],
    "roofline": [
        "tenants", "rows", "k_row", "block", "ms_per_block",
        "updates_per_s", "achieved_bytes_per_s", "peak_fraction",
        "arith_intensity", "bound",
    ],
}


def _replay(svc, ops, block: int):
    """Feed one traffic day through the service; returns (wall_s,
    resolved tickets). Ticks whenever a block's worth of updates is
    pending — the coalescing policy the module docstring describes."""
    tickets = []
    pending = 0
    t0 = time.perf_counter()
    for op in ops:
        if op[0] == "update":
            _, t, items, weights = op
            svc.submit(t, items, weights)
            pending += len(items)
            if pending >= block:
                svc.tick()
                pending = 0
        else:
            _, t, items = op
            tickets.append(svc.query(t, items))
    svc.tick()
    return time.perf_counter() - t0, tickets


def _sampled_parity(svc, spec, sample_rows: np.ndarray) -> bool:
    """Replay the service's recorded block sequence through the serial
    per-row oracle for ``sample_rows``; exact bit-identity per row."""
    import jax

    from repro.sketch import api
    from repro.sketch import bank as bk
    from repro.sketch import tenant as tn

    shards = spec.shards or 1
    router = bk.TenantRouter(spec.tenants, spec.bits, shards)
    fresh = api.make(spec)
    final = svc.session.state
    for r in sample_rows:
        row = jax.tree.map(lambda x: x[int(r)], fresh.bank)
        for ci, cw in svc.trace_blocks:
            row = tn.reference_row_update(row, ci, cw, router, int(r),
                                          spec.variant_id)
        got = jax.tree.map(lambda x: x[int(r)], final.bank)
        for a, b in zip(row, got):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                return False
    return True


def _service_table(tenants: int, block: int, n_updates: int,
                   delete_ratios, k_per_tenant: int, runs: int,
                   parity_rows: int, rng: np.random.Generator) -> List[list]:
    import jax.numpy as jnp

    from repro.serve import SketchService
    from repro.sketch import api
    from repro.sketch import session as ses
    from repro.sketch import tenant as tn

    spec = api.SketchSpec(kind="frequency", k=tenants * k_per_tenant,
                          bits=UNIVERSE_BITS, tenants=tenants)
    rows = []
    entries_before_all = ses.ingest_cache_stats()["entries"]
    for dr in delete_ratios:
        ops = mixed_traffic(tenants, n_updates, delete_ratio=dr,
                            seed=int(dr * 10) + 1)
        n_up = sum(len(o[2]) for o in ops if o[0] == "update")
        n_q = sum(len(o[2]) for o in ops if o[0] == "query")
        entries0 = ses.ingest_cache_stats()["entries"]

        # untimed traced pass: the parity evidence
        svc = SketchService(spec, block=block)
        svc.trace_blocks = []
        _replay(svc, ops, block)
        sample = rng.choice(spec.tenants * (spec.shards or 1),
                            size=min(parity_rows, spec.tenants),
                            replace=False)
        parity_ok = _sampled_parity(svc, spec, sample)

        # timed passes (no trace): min-of-N wall, p99 from the last pass
        best, tickets = float("inf"), []
        for _ in range(runs):
            svc_t = SketchService(spec, block=block)
            wall, tickets = _replay(svc_t, ops, block)
            best = min(best, wall)
        lat = np.asarray([t.latency_s for t in tickets]) \
            if tickets else np.asarray([0.0])
        p99_ms = float(np.percentile(lat, 99) * 1e3)

        # batched point-query throughput: one owner-row gather
        qt = rng.integers(0, tenants, 4096)
        qi = rng.integers(0, 1 << UNIVERSE_BITS, 4096)
        keys = jnp.asarray(tn.pack_keys(qt, qi, UNIVERSE_BITS)
                           .astype(np.int32))
        state = svc_t.session.state
        t_q = min_time(lambda: api.query_many(spec, state, keys),
                       max(runs, 2))
        added = ses.ingest_cache_stats()["entries"] - entries0
        rows.append([
            tenants, spec.shards or 1, dr, n_up, n_q, svc.stats["blocks"],
            n_up / best, len(keys) / t_q, p99_ms,
            len(sample), parity_ok, added,
        ])
        assert parity_ok, f"sampled-row parity failed at delete_ratio={dr}"
    added_all = ses.ingest_cache_stats()["entries"] - entries_before_all
    assert added_all <= 1, (
        f"one-compile-per-layout violated: {added_all} new compiled-ingest "
        f"entries for one tenant layout (ingest_cache_spec regression)")
    return rows


def _fused_vs_sessions(tenants: int, k_per_tenant: int, block: int,
                       n_updates: int, runs: int, parity_tenants: int,
                       rng: np.random.Generator):
    from repro.sketch import api
    from repro.sketch import tenant as tn
    from repro.sketch.session import BlockFeeder, StreamSession

    spec_mt = api.SketchSpec(kind="frequency", k=tenants * k_per_tenant,
                             bits=UNIVERSE_BITS, tenants=tenants)
    spec_1 = api.SketchSpec(kind="frequency", k=k_per_tenant,
                            bits=UNIVERSE_BITS)
    ops = [o for o in mixed_traffic(tenants, n_updates, delete_ratio=0.5,
                                    query_frac=0.0, seed=7)
           if o[0] == "update"]
    n_up = sum(len(o[2]) for o in ops)

    # pre-coalesced fused blocks: the service tick's ingest shape
    keys = np.concatenate([
        tn.pack_keys(np.full(len(o[2]), o[1], np.int64),
                     o[2].astype(np.int64), UNIVERSE_BITS)
        for o in ops]).astype(np.int32)
    weights = np.concatenate([o[3] for o in ops]).astype(np.int32)
    nb = -(-len(keys) // block)
    pad = nb * block - len(keys)
    keys = np.pad(keys, (0, pad))
    weights = np.pad(weights, (0, pad))
    blocks = [(keys[s:s + block], weights[s:s + block])
              for s in range(0, len(keys), block)]

    def run_fused():
        sess = StreamSession(spec_mt, block=block)
        feeder = BlockFeeder(sess)
        for ci, cw in blocks:
            feeder.feed(ci, cw)
        feeder.flush()
        return sess

    # per-tenant-session baseline: each tenant buffers its own substream
    # through its own session (the generous spelling — buffered extend,
    # not one padded dispatch per fragment)
    sess_block = max(64, min(256, block))

    def run_sessions():
        import jax

        sessions = [StreamSession(spec_1, block=sess_block)
                    for _ in range(tenants)]
        for _, t, items, w in ops:
            sessions[t]._append(items, w)  # pre-validated int32 traffic
        for s in sessions:
            s.flush()
        jax.block_until_ready(sessions[-1].state)
        return sessions

    fused = run_fused()       # compile both sides before timing
    run_sessions()
    t_fused = t_sessions = float("inf")
    for _ in range(runs):
        t0 = time.perf_counter()
        fused = run_fused()
        t_fused = min(t_fused, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_sessions()
        t_sessions = min(t_sessions, time.perf_counter() - t0)

    # untimed parity pass: per-tenant twins fed the SAME per-block
    # fragment sequence (identical op order => bit-identical rows)
    sample_t = sorted(rng.choice(tenants, size=min(parity_tenants, tenants),
                                 replace=False).tolist())
    twins = {t: api.make(spec_1) for t in sample_t}
    import jax.numpy as jnp
    for ci, cw in blocks:
        tt, it = tn.unpack_keys(ci.astype(np.int64), UNIVERSE_BITS)
        for t in sample_t:
            m = (tt == t) & (cw != 0)
            if m.any():
                twins[t] = api.update(spec_1, twins[t],
                                      jnp.asarray(it[m].astype(np.int32)),
                                      jnp.asarray(cw[m]))
    bit_identical = True
    for t in sample_t:
        probe = np.unique(np.concatenate(
            [o[2] for o in ops if o[1] == t] or [np.zeros(1, np.int32)]))
        pk = tn.pack_keys(np.full(len(probe), t, np.int64),
                          probe.astype(np.int64), UNIVERSE_BITS)
        q_mt = np.asarray(api.query_many(
            spec_mt, fused.state, jnp.asarray(pk.astype(np.int32))))
        q_1 = np.asarray(api.query_many(
            spec_1, twins[t], jnp.asarray(probe.astype(np.int32))))
        i_mt, v_mt = api.tenant_topk(spec_mt, fused.state, t, k_per_tenant)
        i_1, v_1 = api.topk(spec_1, twins[t], k_per_tenant)
        if not (np.array_equal(q_mt, q_1)
                and np.array_equal(np.asarray(i_mt), np.asarray(i_1))
                and np.array_equal(np.asarray(v_mt), np.asarray(v_1))):
            bit_identical = False
    row = [tenants, k_per_tenant, tenants * k_per_tenant, block, n_up,
           t_fused * 1e3, t_sessions * 1e3,
           t_sessions / max(t_fused, 1e-12),
           len(sample_t), bit_identical]
    return row, t_fused, len(blocks)


def _roofline_row(tenants: int, k_per_tenant: int, block: int,
                  t_fused: float, n_blocks: int) -> list:
    from repro.platform import hw_config
    from repro.roofline.model import sketch_ingest_cost, sketch_roofline

    rows = tenants  # S=1 at the bench shape
    cost = sketch_ingest_cost(num_rows=rows, k=k_per_tenant, block=block)
    wall = t_fused / max(n_blocks, 1)
    roof = sketch_roofline(cost, wall, hw_config())
    return [tenants, rows, k_per_tenant, block, wall * 1e3,
            n_blocks * block / max(t_fused, 1e-12),
            roof["achieved_bytes_per_s"], roof["peak_fraction"],
            roof["arith_intensity"], roof["bound"]]


def run(smoke: bool = False, write_json: bool = True,
        tenants: int = 1024, n_updates: int = 200_000,
        block: int = 8192, k_per_tenant: int = 8, runs: int = 2) -> Dict:
    if smoke:
        tenants, n_updates, block, runs = 32, 4000, 1024, 1
    rng = np.random.default_rng(0)
    results: Dict[str, List[list]] = {}

    results["service"] = _service_table(
        tenants, block, n_updates, (0.0, 0.5), k_per_tenant, runs,
        parity_rows=8 if smoke else 32, rng=rng)

    fvs_row, t_fused, n_blocks = _fused_vs_sessions(
        tenants, k_per_tenant, block, n_updates, runs,
        parity_tenants=8 if smoke else 64, rng=rng)
    results["fused_vs_sessions"] = [fvs_row]

    results["roofline"] = [_roofline_row(tenants, k_per_tenant, block,
                                         t_fused, n_blocks)]

    for name, cols in COLUMNS.items():
        csv_print(name, cols, results[name])

    assert fvs_row[-1], "fused vs per-tenant sessions parity broke"
    if not smoke:
        speedup = fvs_row[7]
        assert speedup >= 2.0, (
            f"fused multi-tenant ingest only {speedup:.2f}x the per-tenant"
            f"-session baseline (acceptance bar: >= 2x)")
    if write_json:
        write_bench_json(results, COLUMNS, "BENCH_service.json")
    return results


if __name__ == "__main__":
    run()
