"""Paper Fig 7: recall / precision of frequent-item reporting vs phi.

Space accounting follows the paper: SS± variants get alpha/eps counters;
Count-Min/Count-Median get (1/eps)·logU counters (their turnstile-model
sizing at the same bit budget).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    DISTRIBUTIONS, csv_print, dist_stream, exact_freqs, make_sketches,
    recall_precision, run_sketch,
)

PHIS = (0.02, 0.01, 0.005)


def run(n_insert: int = 100000, runs: int = 2, seed0: int = 0):
    rows = []
    alpha = 2.0
    log_u = 16  # universe 2^16 — the paper's CM/CMedian space factor
    for dist in DISTRIBUTIONS:
        for phi in PHIS:
            eps = phi / 2.0
            agg = {}
            for r in range(runs):
                stream = dist_stream(dist, n_insert, 0.5, seed=seed0 + r)
                freqs = exact_freqs(stream)
                # paper Fig 7 space: SS± gets alpha/eps counters; CM and
                # CMedian get (1/eps)·logU (their turnstile sizing).
                ss = make_sketches(int(alpha / eps), alpha,
                                   n_stream=len(stream), seed=seed0 + r)
                cm = make_sketches(int(log_u / eps), alpha,
                                   n_stream=len(stream), seed=seed0 + r)
                sketches = {
                    "lazy_sspm": ss["lazy_sspm"],
                    "sspm": ss["sspm"],
                    "count_min": cm["count_min"],
                    "count_median": cm["count_median"],
                }  # CSSS excluded as in the paper (192x space blowup)
                for name, sk in sketches.items():
                    run_sketch(sk, stream)
                    rec, prec = recall_precision(sk, freqs, phi)
                    agg.setdefault(name, []).append((rec, prec))
            for name, vals in agg.items():
                rs = [v[0] for v in vals]
                ps = [v[1] for v in vals]
                rows.append([dist, phi, name, float(np.mean(rs)), float(np.mean(ps))])
    csv_print(
        "fig7_recall_precision",
        ["dist", "phi", "sketch", "recall", "precision"],
        rows,
    )
    return rows


if __name__ == "__main__":
    run()
