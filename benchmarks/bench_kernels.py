"""Kernel benchmarks: parity + interpret-mode throughput for the Pallas
kernels (sketch_update, flash_attention) against their jnp oracles.

For sketch_update the benchmark races THREE generations of the kernel
path per cell (DESIGN.md §3, §14): the seed serial O(B·k) scan, the
split two-phase path (phase 1 in XLA + residual-only launch), and the
production fused tiled kernel (phases 1-2 in ONE ``pallas_call``);
reports both speedups, the residual fraction, bit-identity of the fused
launch against the engine oracle ``bank.update_block_fused``, and the
roofline columns (achieved vs peak bytes/s, arithmetic intensity) from
the sketch-ingest cost model (``repro.roofline.model``) against the
hardware preset for the detected backend (``repro.platform``). Results
are written to ``BENCH_kernels.json`` at the repo root so the perf
trajectory is machine-readable across PRs.

Wall-times here are CPU interpret-mode numbers — correctness and
relative-shape trends only (``peak_fraction`` likewise reads against
the cpu preset); the TPU story is the roofline analysis (DESIGN.md §7).
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (
    UNIVERSE_BITS,
    csv_print,
    dist_stream,
    min_time,
    write_bench_json,
)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_kernels.json")

SKETCH_DISTRIBUTIONS = ("zipf", "binomial", "caida")
SKETCH_SHAPES = ((1024, 1024), (4096, 4096))  # (k, B)

# single source of truth for both csv_print and the JSON artifact
SKETCH_COLUMNS = ["dist", "state", "k", "block", "parity", "bit_identical",
                  "serial_ms", "two_phase_ms", "fused_ms", "speedup",
                  "fused_speedup", "residual_frac", "achieved_bytes_per_s",
                  "peak_fraction", "arith_intensity"]
FLASH_COLUMNS = ["kernel", "seq", "parity", "ms"]
DECODE_COLUMNS = ["kernel", "cache", "parity", "ms"]


def bench_sketch_update(runs: int = 3, shapes=SKETCH_SHAPES):
    from repro.kernels.sketch_update.ops import (
        sketch_block_update,
        sketch_block_update_fused,
        sketch_block_update_serial,
    )
    from repro import sketch as js
    from repro.platform import hw_config
    from repro.roofline.model import sketch_ingest_cost, sketch_roofline
    from repro.sketch import bank as bk

    # end-to-end fused client: route (packed single sort — items live in
    # [0, 2^UNIVERSE_BITS)) + prep + ONE tiled kernel launch, all one jit
    # program; interpret=True pinned so fused vs split is an interpret-
    # comparable measurement on CPU
    router = bk.HashShardRouter(1, UNIVERSE_BITS)

    @jax.jit
    def fused_ingest(state, items, weights):
        bank1 = jax.tree.map(lambda x: x[None], state)
        ri, rw = router.route_dense(items, weights)
        out = sketch_block_update_fused(bank1, ri, rw, 2, True)
        return jax.tree.map(lambda x: x[0], out)

    hw = hw_config()
    rows = []
    for dist in SKETCH_DISTRIBUTIONS:
        for k, block in shapes:
            # three cells per shape: "cold" times an insert block on an
            # empty sketch (residual fraction 1 by construction); "warm"
            # times a second insert block, where the residual fraction is
            # the unseen-unique rate of the distribution; "mixed" times an
            # interleaved insert/delete block on the warm state, covering
            # the unmonitored-deletion spreading path.
            stream = dist_stream(dist, 2 * block, 0.0, seed=1)
            blk1 = stream[:block]
            blk2 = stream[block:2 * block]
            # fresh seed: seed=1 would replay blk1's RNG prefix and make
            # every mixed item monitored
            mixed = dist_stream(dist, block, 0.5, order="interleaved",
                                seed=2)[:block]
            items1 = jnp.asarray(blk1[:, 0], jnp.int32)
            weights1 = jnp.asarray(blk1[:, 1], jnp.int32)
            cold = js.init(k)
            warm = sketch_block_update(cold, items1, weights1)
            warm.ids.block_until_ready()
            for label, state, blk in (
                ("cold", cold, blk1), ("warm", warm, blk2), ("mixed", warm, mixed),
            ):
                items = jnp.asarray(blk[:, 0], jnp.int32)
                weights = jnp.asarray(blk[:, 1], jnp.int32)
                out_k = sketch_block_update(state, items, weights)
                out_j = js.block_update(state, items, weights)
                parity = all(
                    np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(out_k, out_j)
                )
                # fused launch vs the engine oracle: bit-identical, every cell
                out_f = fused_ingest(state, items, weights)
                bank1 = jax.tree.map(lambda x: x[None], state)
                out_o = bk.update_block_fused(bank1, items, weights, router, 2)
                bit_identical = all(
                    np.array_equal(np.asarray(a), np.asarray(b[0]))
                    for a, b in zip(out_f, out_o)
                )
                # warm all paths, then time
                sketch_block_update_serial(state, items, weights).ids.block_until_ready()
                t_two = min_time(lambda: sketch_block_update(state, items, weights), runs)
                t_fused = min_time(lambda: fused_ingest(state, items, weights), runs)
                t_serial = min_time(
                    lambda: sketch_block_update_serial(state, items, weights),
                    runs)
                n_uniq, n_mon, n_res = js.block_partition_stats(state, items, weights)
                res_frac = n_res / max(n_uniq, 1)
                # exact residual lockstep trip count for the cost model:
                # the non-unit insert run length from the fused prep
                ri, rw = router.route_dense(items, weights)
                _, _, _, _, _, nnu, _ = bk.phase1_dense_prep(
                    bank1, ri, rw, 2)
                trips = int(np.asarray(nnu).max())
                cost = sketch_ingest_cost(num_rows=1, k=k, block=block,
                                          residual_trips=trips)
                roof = sketch_roofline(cost, t_fused, hw)
                rows.append([
                    dist, label, k, block, parity, bit_identical,
                    t_serial * 1e3, t_two * 1e3, t_fused * 1e3,
                    t_serial / max(t_two, 1e-12),
                    t_two / max(t_fused, 1e-12), res_frac,
                    roof["achieved_bytes_per_s"], roof["peak_fraction"],
                    roof["arith_intensity"],
                ])
    csv_print("kernel_sketch_update", SKETCH_COLUMNS, rows)
    return rows


def bench_flash_attention(runs: int = 2):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rows = []
    for (B, S, H, KV, hd) in ((1, 256, 4, 2, 64), (1, 512, 8, 2, 128)):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        parity = bool(jnp.allclose(out, ref, atol=3e-5, rtol=3e-5))
        t0 = time.perf_counter()
        for _ in range(runs):
            flash_attention(q, k, v, causal=True).block_until_ready()
        dt = (time.perf_counter() - t0) / runs
        rows.append([f"flash_B{B}_S{S}_H{H}", S, parity, dt * 1e3])
    csv_print("kernel_flash_attention", FLASH_COLUMNS, rows)
    return rows


def bench_decode_attention(runs: int = 2):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    rows = []
    for (B, KV, G, hd, C) in ((2, 2, 4, 64, 512), (1, 4, 2, 128, 2048)):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, C, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, C, KV, hd), jnp.float32)
        valid = jax.random.uniform(ks[3], (B, C)) < 0.8
        ctx, mass = decode_attention(q, k, v, valid)
        ctx_r, mass_r = decode_attention_ref(q, k, v, valid)
        parity = bool(
            jnp.allclose(ctx, ctx_r, atol=3e-5, rtol=3e-5)
            and jnp.allclose(mass, mass_r, atol=2e-5, rtol=2e-4)
        )
        t0 = time.perf_counter()
        for _ in range(runs):
            decode_attention(q, k, v, valid)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / runs
        rows.append([f"decode_C{C}_KV{KV}", C, parity, dt * 1e3])
    csv_print("kernel_decode_attention", DECODE_COLUMNS, rows)
    return rows


def _write_json(results: dict, path: str = JSON_PATH) -> None:
    write_bench_json(results, {
        "sketch_update": SKETCH_COLUMNS,
        "flash_attention": FLASH_COLUMNS,
        "decode_attention": DECODE_COLUMNS,
    }, path)


def run(smoke: bool = False, write_json: bool = True, **kw):
    if smoke:
        results = {
            "sketch_update": bench_sketch_update(runs=1, shapes=((256, 256),)),
            "flash_attention": bench_flash_attention(runs=1),
            "decode_attention": bench_decode_attention(runs=1),
        }
    else:
        results = {
            "sketch_update": bench_sketch_update(),
            "flash_attention": bench_flash_attention(),
            "decode_attention": bench_decode_attention(),
        }
    if write_json and not smoke:
        _write_json(results)
    return results


if __name__ == "__main__":
    run()
