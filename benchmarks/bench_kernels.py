"""Kernel benchmarks: parity + interpret-mode throughput for the Pallas
kernels (sketch_update, flash_attention) against their jnp oracles.

Wall-times here are CPU interpret-mode numbers — correctness and
relative-shape trends only; the TPU story is the roofline analysis.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_print
from repro.core.streams import bounded_stream


def bench_sketch_update(runs: int = 2):
    from repro.kernels.sketch_update.ops import sketch_block_update
    from repro.kernels.sketch_update.ref import sketch_update_ref
    from repro.sketch import jax_sketch as js

    rows = []
    for k, block in ((1024, 1024), (4096, 4096)):
        stream = bounded_stream("zipf", block, 0.5, seed=1)[:block]
        items = jnp.asarray(stream[:, 0], jnp.int32)
        weights = jnp.asarray(stream[:, 1], jnp.int32)
        state = js.init(k)

        out_k = sketch_block_update(state, items, weights)
        rid, rcnt, rerr = sketch_update_ref(
            state.ids, state.counts, state.errors, items, weights
        )
        parity = (
            np.array_equal(np.asarray(out_k.ids), np.asarray(rid))
            and np.array_equal(np.asarray(out_k.counts), np.asarray(rcnt))
        )

        t0 = time.perf_counter()
        for _ in range(runs):
            sketch_block_update(state, items, weights).ids.block_until_ready()
        dt = (time.perf_counter() - t0) / runs
        rows.append([f"sketch_update_k{k}", block, parity, dt * 1e3])
    csv_print("kernel_sketch_update", ["kernel", "block", "parity", "ms"], rows)
    return rows


def bench_flash_attention(runs: int = 2):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rows = []
    for (B, S, H, KV, hd) in ((1, 256, 4, 2, 64), (1, 512, 8, 2, 128)):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        parity = bool(jnp.allclose(out, ref, atol=3e-5, rtol=3e-5))
        t0 = time.perf_counter()
        for _ in range(runs):
            flash_attention(q, k, v, causal=True).block_until_ready()
        dt = (time.perf_counter() - t0) / runs
        rows.append([f"flash_B{B}_S{S}_H{H}", S, parity, dt * 1e3])
    csv_print("kernel_flash_attention", ["kernel", "seq", "parity", "ms"], rows)
    return rows


def bench_decode_attention(runs: int = 2):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    rows = []
    for (B, KV, G, hd, C) in ((2, 2, 4, 64, 512), (1, 4, 2, 128, 2048)):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, C, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, C, KV, hd), jnp.float32)
        valid = jax.random.uniform(ks[3], (B, C)) < 0.8
        ctx, mass = decode_attention(q, k, v, valid)
        ctx_r, mass_r = decode_attention_ref(q, k, v, valid)
        parity = bool(
            jnp.allclose(ctx, ctx_r, atol=3e-5, rtol=3e-5)
            and jnp.allclose(mass, mass_r, atol=2e-5, rtol=2e-4)
        )
        t0 = time.perf_counter()
        for _ in range(runs):
            decode_attention(q, k, v, valid)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / runs
        rows.append([f"decode_C{C}_KV{KV}", C, parity, dt * 1e3])
    csv_print("kernel_decode_attention", ["kernel", "cache", "parity", "ms"], rows)
    return rows


def run(**kw):
    return {
        "sketch_update": bench_sketch_update(),
        "flash_attention": bench_flash_attention(),
        "decode_attention": bench_decode_attention(),
    }


if __name__ == "__main__":
    run()
