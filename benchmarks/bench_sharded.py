"""Sharded SpaceSaving± bank vs the single sketch at equal total budget.

Three tables, all written to ``BENCH_sharded.json`` at the repo root:

  * **ingest** — block-ingest wall time of the fused sharded launch
    (``sharded.update_block``, packed-sort router + banked residual
    loop) against the production single-sketch ``blocks.block_update``,
    S ∈ {1, 2, 4, 8} at the same total counter budget, warm states.
    The headline acceptance cell (zipf, B = 16384, budget 1024) tracks
    the ≥2x S=4 speedup; every sharded cell also re-checks bit-identity
    against the route-then-update-each-shard-serially reference.
  * **quality** — recall / precision at phi ∈ {0.005, 0.01} and the max
    per-item error of the sharded bank vs the single sketch on full
    mixed insert/delete streams (alpha = 2), same budget: the
    shard-by-hash query path adds NO merge error, so recall stays 1.0
    and precision matches the single sketch.
Wall-times are 2-core CPU numbers — relative trends only (DESIGN.md §7,
§9); parity and bit-identity are exact booleans.
"""
from __future__ import annotations

import os

import numpy as np

import jax.numpy as jnp

from benchmarks.common import (
    UNIVERSE_BITS,
    adversarial_stream,
    csv_print,
    dist_stream,
    exact_freqs,
    min_time,
    recall_precision,
    session_overhead,
    write_bench_json,
)
from repro.sketch import api, bank as bkmod, blocks, sharded as shd, state as st

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_sharded.json")

BUDGET = 1024
SHARD_COUNTS = (1, 2, 4, 8)
INGEST_CELLS = (  # (dist, block)
    ("zipf", 4096),
    ("zipf", 8192),
    ("zipf", 16384),
    ("caida", 16384),
)

INGEST_COLUMNS = ["dist", "block", "budget", "shards", "ms_per_block",
                  "items_per_s", "speedup_vs_single", "bit_identical"]
QUALITY_COLUMNS = ["dist", "alpha", "budget", "shards", "phi", "recall",
                   "precision", "max_err"]
SESSION_COLUMNS = ["dist", "block", "budget", "shards", "ms_direct",
                   "ms_session", "overhead_pct"]


def _banks_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a.bank, b.bank))


def bench_ingest(runs: int = 7, budget: int = BUDGET,
                 cells=INGEST_CELLS, shard_counts=SHARD_COUNTS):
    rows = []
    for dist, block in cells:
        stream = dist_stream(dist, 2 * block, 0.0, seed=1)
        i1 = jnp.asarray(stream[:block, 0], jnp.int32)
        w1 = jnp.asarray(stream[:block, 1], jnp.int32)
        i2 = jnp.asarray(stream[block:2 * block, 0], jnp.int32)
        w2 = jnp.asarray(stream[block:2 * block, 1], jnp.int32)
        t_single = None
        for S in shard_counts:
            if S == 1:
                warm = blocks.block_update(st.init(budget), i1, w1)
                t = min_time(lambda: blocks.block_update(warm, i2, w2), runs)
                t_single = t
                ok = True
            else:
                warm = shd.update_block(shd.init(budget, S), i1, w1,
                                        universe_bits=UNIVERSE_BITS)
                t = min_time(
                    lambda: shd.update_block(warm, i2, w2,
                                             universe_bits=UNIVERSE_BITS),
                    runs)
                ref = shd.update_block_serial_reference(
                    shd.update_block_serial_reference(
                        shd.init(budget, S), i1, w1,
                        universe_bits=UNIVERSE_BITS),
                    i2, w2, universe_bits=UNIVERSE_BITS)
                got = shd.update_block(warm, i2, w2,
                                       universe_bits=UNIVERSE_BITS)
                ok = _banks_equal(got, ref)
            rows.append([dist, block, budget, S, t * 1e3, block / t,
                         t_single / t, ok])
    csv_print("sharded_ingest", INGEST_COLUMNS, rows)
    return rows


def bench_quality(n_insert: int = 20000, budget: int = BUDGET,
                  shard_counts=SHARD_COUNTS, block: int = 4096):
    rows = []
    alpha = 2.0
    # zipf/caida random interleaved deletions + the paper's adversarial
    # case (targeted deletions of the heaviest items, inserts first):
    # max unmonitored-deletion spreading, the worst case for routing too.
    cells = (
        ("zipf", dist_stream("zipf", n_insert, 0.5, order="interleaved",
                             seed=3)),
        ("caida", dist_stream("caida", n_insert, 0.5, order="interleaved",
                              seed=3)),
        ("zipf_adversarial", adversarial_stream(n_insert, 0.5, seed=3)),
    )
    from repro.sketch.session import StreamSession

    for dist, stream in cells:
        freqs = exact_freqs(stream)
        cand = np.nonzero(freqs > 0)[0]
        q = jnp.asarray(cand, jnp.int32)
        for S in shard_counts:
            # single and sharded are the SAME session client: one spec
            # field apart (the thin-consumer contract of DESIGN.md §11)
            spec = api.SketchSpec(kind="frequency", k=budget,
                                  shards=None if S == 1 else S,
                                  bits=UNIVERSE_BITS, backend="bank")
            sess = StreamSession(spec, block=block)
            sess.extend(stream[:, 0].astype(np.int32),
                        stream[:, 1].astype(np.int32))
            est = np.asarray(sess.query_many(q), np.int64)
            max_err = int(np.abs(est - freqs[cand]).max())
            for phi in (0.005, 0.01):
                recall, precision = recall_precision(None, freqs, phi,
                                                     est=est)
                rows.append([dist, alpha, budget, S, phi, recall, precision,
                             max_err])
    csv_print("sharded_quality", QUALITY_COLUMNS, rows)
    return rows


def bench_session(budget: int = BUDGET, S: int = 4, block: int = 16384,
                  n_blocks: int = 16, runs: int = 9):
    """StreamSession dispatch overhead vs the raw fused engine call.

    The DESIGN.md §11 acceptance cell: both sides run the SAME evolving
    (zipf, B, S) block sequence — direct ``bank.update_block_fused``
    with a pinned router vs the session's cached jitted ingest — so the
    measured gap is pure session overhead (<5% required).
    """
    import jax

    stream = dist_stream("zipf", (n_blocks + 1) * block, 0.0, seed=1)
    spec = api.SketchSpec(kind="frequency", k=budget, shards=S,
                          bits=UNIVERSE_BITS, backend="bank")
    router = bkmod.HashShardRouter(S, UNIVERSE_BITS)
    direct = jax.jit(lambda s_, i, w: shd.ShardedSketch(
        bank=bkmod.update_block_fused(s_.bank, i, w, router,
                                      spec.variant_id)))
    warm = lambda i, w: shd.update_block(shd.init(budget, S), i, w,
                                         universe_bits=UNIVERSE_BITS)
    t_d, t_s, pct = session_overhead(spec, direct, warm, stream, block,
                                     n_blocks, runs)
    rows = [["zipf", block, budget, S, t_d / n_blocks * 1e3,
             t_s / n_blocks * 1e3, pct]]
    csv_print("session_overhead", SESSION_COLUMNS, rows)
    return rows


def _write_json(results: dict, path: str = JSON_PATH) -> None:
    write_bench_json(results,
                     {"ingest": INGEST_COLUMNS, "quality": QUALITY_COLUMNS,
                      "session_overhead": SESSION_COLUMNS},
                     path)


def run(runs: int = 7, write_json: bool = True, smoke: bool = False, **kw):
    if smoke:
        results = {
            "ingest": bench_ingest(runs=2, budget=128,
                                   cells=(("zipf", 1024),),
                                   shard_counts=(1, 4)),
            "quality": bench_quality(n_insert=2000, budget=128,
                                     shard_counts=(1, 4), block=1024),
            "session_overhead": bench_session(budget=128, block=1024,
                                              n_blocks=2, runs=2),
        }
    else:
        results = {
            "ingest": bench_ingest(runs=runs),
            "quality": bench_quality(),
            "session_overhead": bench_session(runs=runs),
        }
    if write_json and not smoke:
        _write_json(results)
    return results


if __name__ == "__main__":
    run()
