"""Sharded SpaceSaving± bank vs the single sketch at equal total budget.

Three tables, all written to ``BENCH_sharded.json`` at the repo root:

  * **ingest** — block-ingest wall time of the fused sharded launch
    (``sharded.update_block``, packed-sort router + banked residual
    loop) against the production single-sketch ``blocks.block_update``,
    S ∈ {1, 2, 4, 8} at the same total counter budget, warm states.
    The headline acceptance cell (zipf, B = 16384, budget 1024) tracks
    the ≥2x S=4 speedup; every sharded cell also re-checks bit-identity
    against the route-then-update-each-shard-serially reference.
  * **quality** — recall / precision at phi ∈ {0.005, 0.01} and the max
    per-item error of the sharded bank vs the single sketch on full
    mixed insert/delete streams (alpha = 2), same budget: the
    shard-by-hash query path adds NO merge error, so recall stays 1.0
    and precision matches the single sketch.
Wall-times are 2-core CPU numbers — relative trends only (DESIGN.md §7,
§9); parity and bit-identity are exact booleans.
"""
from __future__ import annotations

import os

import numpy as np

import jax.numpy as jnp

from benchmarks.common import (
    UNIVERSE_BITS,
    adversarial_stream,
    csv_print,
    dist_stream,
    exact_freqs,
    min_time,
    recall_precision,
    stream_blocks,
    write_bench_json,
)
from repro.sketch import blocks, sharded as shd, state as st

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_sharded.json")

BUDGET = 1024
SHARD_COUNTS = (1, 2, 4, 8)
INGEST_CELLS = (  # (dist, block)
    ("zipf", 4096),
    ("zipf", 8192),
    ("zipf", 16384),
    ("caida", 16384),
)

INGEST_COLUMNS = ["dist", "block", "budget", "shards", "ms_per_block",
                  "items_per_s", "speedup_vs_single", "bit_identical"]
QUALITY_COLUMNS = ["dist", "alpha", "budget", "shards", "phi", "recall",
                   "precision", "max_err"]


def _banks_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a.bank, b.bank))


def bench_ingest(runs: int = 7, budget: int = BUDGET,
                 cells=INGEST_CELLS, shard_counts=SHARD_COUNTS):
    rows = []
    for dist, block in cells:
        stream = dist_stream(dist, 2 * block, 0.0, seed=1)
        i1 = jnp.asarray(stream[:block, 0], jnp.int32)
        w1 = jnp.asarray(stream[:block, 1], jnp.int32)
        i2 = jnp.asarray(stream[block:2 * block, 0], jnp.int32)
        w2 = jnp.asarray(stream[block:2 * block, 1], jnp.int32)
        t_single = None
        for S in shard_counts:
            if S == 1:
                warm = blocks.block_update(st.init(budget), i1, w1)
                t = min_time(lambda: blocks.block_update(warm, i2, w2), runs)
                t_single = t
                ok = True
            else:
                warm = shd.update_block(shd.init(budget, S), i1, w1,
                                        universe_bits=UNIVERSE_BITS)
                t = min_time(
                    lambda: shd.update_block(warm, i2, w2,
                                             universe_bits=UNIVERSE_BITS),
                    runs)
                ref = shd.update_block_serial_reference(
                    shd.update_block_serial_reference(
                        shd.init(budget, S), i1, w1,
                        universe_bits=UNIVERSE_BITS),
                    i2, w2, universe_bits=UNIVERSE_BITS)
                got = shd.update_block(warm, i2, w2,
                                       universe_bits=UNIVERSE_BITS)
                ok = _banks_equal(got, ref)
            rows.append([dist, block, budget, S, t * 1e3, block / t,
                         t_single / t, ok])
    csv_print("sharded_ingest", INGEST_COLUMNS, rows)
    return rows


def bench_quality(n_insert: int = 20000, budget: int = BUDGET,
                  shard_counts=SHARD_COUNTS, block: int = 4096):
    rows = []
    alpha = 2.0
    # zipf/caida random interleaved deletions + the paper's adversarial
    # case (targeted deletions of the heaviest items, inserts first):
    # max unmonitored-deletion spreading, the worst case for routing too.
    cells = (
        ("zipf", dist_stream("zipf", n_insert, 0.5, order="interleaved",
                             seed=3)),
        ("caida", dist_stream("caida", n_insert, 0.5, order="interleaved",
                              seed=3)),
        ("zipf_adversarial", adversarial_stream(n_insert, 0.5, seed=3)),
    )
    for dist, stream in cells:
        freqs = exact_freqs(stream)
        items, weights, nb = stream_blocks(stream, block)
        cand = np.nonzero(freqs > 0)[0]
        q = jnp.asarray(cand, jnp.int32)
        for S in shard_counts:
            if S == 1:
                sk = st.init(budget)
                for b in range(nb):
                    sl = slice(b * block, (b + 1) * block)
                    sk = blocks.block_update(
                        sk, jnp.asarray(items[sl]), jnp.asarray(weights[sl]))
                est = np.asarray(st.query_many(sk, q), np.int64)
            else:
                bank = shd.init(budget, S)
                for b in range(nb):
                    sl = slice(b * block, (b + 1) * block)
                    bank = shd.update_block(
                        bank, jnp.asarray(items[sl]), jnp.asarray(weights[sl]),
                        universe_bits=UNIVERSE_BITS)
                est = np.asarray(shd.query_many(bank, q), np.int64)
            max_err = int(np.abs(est - freqs[cand]).max())
            for phi in (0.005, 0.01):
                recall, precision = recall_precision(None, freqs, phi,
                                                     est=est)
                rows.append([dist, alpha, budget, S, phi, recall, precision,
                             max_err])
    csv_print("sharded_quality", QUALITY_COLUMNS, rows)
    return rows


def _write_json(results: dict, path: str = JSON_PATH) -> None:
    write_bench_json(results,
                     {"ingest": INGEST_COLUMNS, "quality": QUALITY_COLUMNS},
                     path)


def run(runs: int = 7, write_json: bool = True, smoke: bool = False, **kw):
    if smoke:
        results = {
            "ingest": bench_ingest(runs=2, budget=128,
                                   cells=(("zipf", 1024),),
                                   shard_counts=(1, 4)),
            "quality": bench_quality(n_insert=2000, budget=128,
                                     shard_counts=(1, 4), block=1024),
        }
    else:
        results = {
            "ingest": bench_ingest(runs=runs),
            "quality": bench_quality(),
        }
    if write_json and not smoke:
        _write_json(results)
    return results


if __name__ == "__main__":
    run()
