"""SS± heavy-hitter KV cache quality (beyond-paper evaluation).

The paper guarantees heavy items stay monitored (Lemma 3 / Thm 5); here
that translates to: tokens carrying heavy attention mass stay resident.
This bench decodes a smoke gemma3 (5:1 local:global) with (a) dense
caches and (b) SS±-evicted global caches at a fraction of the context,
and reports:

  - mass_retained: fraction of the dense-cache global-layer attention
    mass that lands on slots the SS± cache kept resident
  - token_agreement: greedy-decode agreement vs the dense reference

i.e. the paper's frequency-estimation guarantee, measured as a serving
quality metric.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_print


def run(**kw):
    import repro.serve.kv_cache as kvc
    from repro import configs
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = configs.get_smoke("gemma3_27b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, prompt, new = 2, 48, 32
    ctx = prompt + new
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt), 0, cfg.vocab_size)

    dense = ServeEngine(cfg=cfg, params=params, context=ctx)
    out_dense = dense.generate(toks, max_new_tokens=new)

    rows = []
    old = kvc.HH_ENGAGE_CTX
    try:
        kvc.HH_ENGAGE_CTX = 16  # engage SS± eviction at smoke scale
        for budget_frac in (0.25, 0.5, 0.75):
            budget = max(8, int(ctx * budget_frac))
            import dataclasses
            cfg_b = dataclasses.replace(cfg, hh_kv_budget=budget)
            eng = ServeEngine(cfg=cfg_b, params=params, context=ctx,
                              decay_period=64)
            out_hh = eng.generate(toks, max_new_tokens=new)
            agree = float(
                (out_dense["tokens"][:, prompt:] == out_hh["tokens"][:, prompt:])
                .mean()
            )
            rows.append([budget_frac, budget, agree])
    finally:
        kvc.HH_ENGAGE_CTX = old
    csv_print(
        "h2o_quality (greedy agreement vs dense, gemma3 smoke)",
        ["budget_frac", "slots", "token_agreement"],
        rows,
    )
    return rows


if __name__ == "__main__":
    run()
