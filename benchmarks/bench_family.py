"""Race the whole SpaceSaving± family on one harness (BENCH_family.json).

Every variant the spec grammar can spell — plain SS± ('sspm'), lazy
deletion ('lazy'), Double SS± ('double'), unbiased SS± ('unbiased') and
the deterministic CR-precis linear baseline ('crprecis') — runs through
the SAME :class:`StreamSession` driver (``common.run_spec``) at EQUAL
counter budgets, so the table is a true accuracy-vs-space frontier:

  * zipf bounded-deletion streams at delete ratios {0%, 50%, 93%}
    (93% is the family paper's extreme: alpha = 1/(1-0.93) ~ 14.3);
  * phi-heavy-hitter recall/precision and frequency-weighted MSE
    against exact counts;
  * a Ganguly-style lower-bound floor per (ratio, budget) cell:
    ``lb_error = alpha * (I - D) / k`` — the error any k-counter
    deterministic summary must pay in the bounded-deletion model
    (PAPERS.md, Ganguly '07) — so the frontier plots have an
    information-theoretic floor to sit on.

The family acceptance row: at equal space, 'double' recall is >= plain
'sspm' recall on every (ratio, budget) cell (its deletions never spread
error across survivors — they land in the second bank).

Wall-times are 2-core CPU numbers; trends only (DESIGN.md §7).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import (
    UNIVERSE_BITS,
    csv_print,
    exact_freqs,
    recall_precision,
    run_spec,
    zipf_stream,
    write_bench_json,
)
from repro.sketch import api

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_REPO_ROOT, "BENCH_family.json")

VARIANTS = ("sspm", "lazy", "double", "unbiased", "crprecis")
RATIOS = (0.0, 0.5, 0.93)
BUDGETS = (256, 512, 1024)
BLOCK = 4096

COLUMNS = ["dist", "ratio", "alpha", "budget", "variant", "ms_ingest",
           "recall", "precision", "wmse", "lb_error"]


def _spec(variant: str, budget: int, alpha: float) -> api.SketchSpec:
    if variant == "crprecis":
        return api.SketchSpec(kind="frequency", k=budget,
                              backend="crprecis", bits=UNIVERSE_BITS)
    return api.SketchSpec(kind="frequency", k=budget, variant=variant,
                          alpha=alpha, bits=UNIVERSE_BITS)


def _weighted_mse(sess, freqs: np.ndarray) -> float:
    """Frequency-weighted MSE over the live support: queries arrive
    proportionally to item frequency, so each id's squared error is
    weighted by its true count (the family paper's estimation metric)."""
    cand = np.nonzero(freqs > 0)[0]
    est = np.asarray(sess.query_many(cand), dtype=np.float64)
    f = freqs[cand].astype(np.float64)
    return float((f * (est - f) ** 2).sum() / f.sum())


def run(n_insert: int = 20_000, budgets=BUDGETS, ratios=RATIOS,
        runs: int = 2, phi: float = 0.005, smoke: bool = False,
        write_json: bool = True) -> None:
    if smoke:
        # phi is raised with the shrunken stream so the heavy threshold
        # phi * live stays above 1 count — at the default phi every live
        # singleton is "heavy", which no k-counter summary can track
        n_insert, budgets, ratios, runs, phi = \
            2_000, (128,), (0.0, 0.93), 1, 0.05
    rows = []
    recall_by = {}
    for ratio in ratios:
        alpha = 1.0 if ratio == 0.0 else 1.0 / (1.0 - ratio)
        stream = zipf_stream(n_insert, ratio, seed=7, order="interleaved")
        freqs = exact_freqs(stream)
        live = float(freqs.sum())
        for budget in budgets:
            lb = alpha * live / budget
            for variant in VARIANTS:
                spec = _spec(variant, budget, alpha)
                sec, sess = run_spec(spec, stream, BLOCK, runs=runs)
                recall, precision = recall_precision(sess, freqs, phi)
                wmse = _weighted_mse(sess, freqs)
                rows.append(["zipf", ratio, round(alpha, 3), budget,
                             variant, 1e3 * sec, recall, precision, wmse,
                             lb])
                recall_by[(ratio, budget, variant)] = recall
    csv_print("family_frontier", COLUMNS, rows)

    # the family acceptance row: double's recall >= plain sspm's at
    # every equal-space cell (printed, not asserted — the JSON artifact
    # is the record; tests/test_bench_run.py just needs the bench green)
    worst = min((recall_by[(r, b, "double")] - recall_by[(r, b, "sspm")]
                 for r in ratios for b in budgets), default=0.0)
    print(f"\n# double-vs-sspm recall margin (min over cells): "
          f"{worst:+.4f} {'OK' if worst >= 0 else 'REGRESSION'}")

    if write_json:
        write_bench_json({"family_frontier": rows},
                         {"family_frontier": COLUMNS}, JSON_PATH)


if __name__ == "__main__":
    run()
